//! Dense host primitives for the native backend: GEMM layout adapters
//! over the runtime-dispatched micro-kernel in [`gemm`](super::gemm)
//! (`PACKMAMBA_GEMM` tier: scalar reference / safe blocked / AVX2+FMA),
//! RMSNorm, activations, blocked layout transposes, and the masked
//! cross-entropy head.  All operate on flat row-major `f32` slices;
//! shapes travel as explicit dimensions.  Parallel routines dispatch
//! onto the persistent `WorkerPool` — no per-call thread spawns.
//!
//! Every routine has an `_into` form that writes caller-provided buffers
//! — the allocation-free surface `model` drives through the `StepArena` —
//! plus a thin allocating wrapper for tests, benches, and one-shot use.
//!
//! Determinism: every parallel routine assigns each output chunk a fixed
//! serial computation, so results are bit-identical for any thread count
//! — the invariant the data-parallel replica check relies on.

use super::gemm::{self, GemmScratch, Layout};
use crate::util::threadpool::parallel_chunks2_mut;
use crate::util::trace::{self, Op};

pub(crate) use super::gemm::effective_threads;

/// `(m, k) @ (k, n) + beta·out -> out`.
#[allow(clippy::too_many_arguments)]
pub fn matmul_into(
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    beta: f32,
    out: &mut [f32],
    threads: usize,
    scratch: &mut GemmScratch,
) {
    gemm::gemm_into(Layout::NN, m, k, n, a, b, beta, out, threads, scratch);
}

/// `(m, k) @ (n, k)^T + beta·out -> out` — right operand transposed
/// (e.g. `dy @ W^T`, logits against the tied embedding).
#[allow(clippy::too_many_arguments)]
pub fn matmul_nt_into(
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    beta: f32,
    out: &mut [f32],
    threads: usize,
    scratch: &mut GemmScratch,
) {
    gemm::gemm_into(Layout::NT, m, k, n, a, b, beta, out, threads, scratch);
}

/// `(t, m)^T @ (t, n) + beta·out -> out` — left operand transposed
/// (weight gradients `x^T @ dy`, fused into the grad buffer via beta=1).
#[allow(clippy::too_many_arguments)]
pub fn matmul_tn_into(
    a: &[f32],
    t: usize,
    m: usize,
    b: &[f32],
    n: usize,
    beta: f32,
    out: &mut [f32],
    threads: usize,
    scratch: &mut GemmScratch,
) {
    gemm::gemm_into(Layout::TN, m, t, n, a, b, beta, out, threads, scratch);
}

/// `(m, k) @ (k, n) -> (m, n)`.
pub fn matmul(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, threads: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_into(a, m, k, b, n, 0.0, &mut out, threads, &mut GemmScratch::new());
    out
}

/// `(m, k) @ (n, k)^T -> (m, n)`.
pub fn matmul_nt(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, threads: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_nt_into(a, m, k, b, n, 0.0, &mut out, threads, &mut GemmScratch::new());
    out
}

/// `(t, m)^T @ (t, n) -> (m, n)`.
pub fn matmul_tn(a: &[f32], t: usize, m: usize, b: &[f32], n: usize, threads: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_tn_into(a, t, m, b, n, 0.0, &mut out, threads, &mut GemmScratch::new());
    out
}

/// Transpose tile edge (square blocking keeps both source and
/// destination lines cache-resident instead of striding one of them
/// through the whole plane per row).
const TRANS_BLOCK: usize = 32;

/// `(B, L, D)` token-major → `(B, D, L)` channel-major, blocked.
pub fn to_channel_major_into(x: &[f32], b: usize, l: usize, d: usize, out: &mut [f32]) {
    assert_eq!(x.len(), b * l * d);
    assert_eq!(out.len(), b * l * d);
    for bi in 0..b {
        let src = &x[bi * l * d..(bi + 1) * l * d];
        let dst = &mut out[bi * l * d..(bi + 1) * l * d];
        for t0 in (0..l).step_by(TRANS_BLOCK) {
            let t1 = (t0 + TRANS_BLOCK).min(l);
            for c0 in (0..d).step_by(TRANS_BLOCK) {
                let c1 = (c0 + TRANS_BLOCK).min(d);
                for t in t0..t1 {
                    for c in c0..c1 {
                        dst[c * l + t] = src[t * d + c];
                    }
                }
            }
        }
    }
}

/// `(B, D, L)` channel-major → `(B, L, D)` token-major, blocked.
pub fn to_token_major_into(x: &[f32], b: usize, d: usize, l: usize, out: &mut [f32]) {
    assert_eq!(x.len(), b * l * d);
    assert_eq!(out.len(), b * l * d);
    for bi in 0..b {
        let src = &x[bi * l * d..(bi + 1) * l * d];
        let dst = &mut out[bi * l * d..(bi + 1) * l * d];
        for c0 in (0..d).step_by(TRANS_BLOCK) {
            let c1 = (c0 + TRANS_BLOCK).min(d);
            for t0 in (0..l).step_by(TRANS_BLOCK) {
                let t1 = (t0 + TRANS_BLOCK).min(l);
                for c in c0..c1 {
                    for t in t0..t1 {
                        dst[t * d + c] = src[c * l + t];
                    }
                }
            }
        }
    }
}

/// `(B, L, D)` token-major → `(B, D, L)` channel-major.
pub fn to_channel_major(x: &[f32], b: usize, l: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    to_channel_major_into(x, b, l, d, &mut out);
    out
}

/// `(B, D, L)` channel-major → `(B, L, D)` token-major.
pub fn to_token_major(x: &[f32], b: usize, d: usize, l: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    to_token_major_into(x, b, d, l, &mut out);
    out
}

pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

pub fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// d(silu)/dx.
pub fn dsilu(x: f32) -> f32 {
    let s = sigmoid(x);
    s * (1.0 + x * (1.0 - s))
}

/// Numerically stable softplus.
pub fn softplus(x: f32) -> f32 {
    x.max(0.0) + (-x.abs()).exp().ln_1p()
}

/// RMSNorm forward over rows of length `d` into `(y, inv)` with
/// `inv[t] = 1/sqrt(mean(x_t^2) + eps)`.
pub fn rms_norm_fwd_into(x: &[f32], d: usize, w: &[f32], eps: f32, y: &mut [f32], inv: &mut [f32]) {
    let _sp = trace::span(Op::RmsNormFwd);
    assert_eq!(x.len() % d, 0);
    assert_eq!(w.len(), d);
    let t = x.len() / d;
    assert_eq!(y.len(), x.len());
    assert_eq!(inv.len(), t);
    for ti in 0..t {
        let row = &x[ti * d..(ti + 1) * d];
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + eps).sqrt();
        inv[ti] = r;
        let orow = &mut y[ti * d..(ti + 1) * d];
        for ((o, &xv), &wv) in orow.iter_mut().zip(row).zip(w) {
            *o = xv * r * wv;
        }
    }
}

/// RMSNorm forward; returns `(y, inv)`.
pub fn rms_norm_fwd(x: &[f32], d: usize, w: &[f32], eps: f32) -> (Vec<f32>, Vec<f32>) {
    let mut y = vec![0.0f32; x.len()];
    let mut inv = vec![0.0f32; x.len() / d];
    rms_norm_fwd_into(x, d, w, eps, &mut y, &mut inv);
    (y, inv)
}

/// RMSNorm backward: writes `dx` and **accumulates** into `dw_acc`.
pub fn rms_norm_bwd_into(
    x: &[f32],
    d: usize,
    w: &[f32],
    inv: &[f32],
    dy: &[f32],
    dx: &mut [f32],
    dw_acc: &mut [f32],
) {
    let _sp = trace::span(Op::RmsNormBwd);
    let t = x.len() / d;
    assert_eq!(dx.len(), x.len());
    assert_eq!(dw_acc.len(), d);
    for ti in 0..t {
        let row = &x[ti * d..(ti + 1) * d];
        let grow = &dy[ti * d..(ti + 1) * d];
        let r = inv[ti];
        let mut dot = 0.0f32; // sum_i dy_i * w_i * x_i
        for ((&xv, &gv), &wv) in row.iter().zip(grow).zip(w) {
            dot += gv * wv * xv;
        }
        let scale = r * r * r / d as f32 * dot;
        let orow = &mut dx[ti * d..(ti + 1) * d];
        for i in 0..d {
            orow[i] = r * w[i] * grow[i] - row[i] * scale;
            dw_acc[i] += row[i] * r * grow[i];
        }
    }
}

/// RMSNorm backward; returns `(dx, dw)`.
pub fn rms_norm_bwd(
    x: &[f32],
    d: usize,
    w: &[f32],
    inv: &[f32],
    dy: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; x.len()];
    let mut dw = vec![0.0f32; d];
    rms_norm_bwd_into(x, d, w, inv, dy, &mut dx, &mut dw);
    (dx, dw)
}

/// Rows per cross-entropy reduction chunk.  Fixed: the loss is a sum of
/// per-chunk f64 partials, so the grouping (and therefore the rounding)
/// must not depend on the thread count — the determinism invariant DP
/// replicas rely on.
const CE_ROWS: usize = 64;

/// Number of `f64` partial slots [`cross_entropy_into`] needs for `t`
/// targets (size `loss_parts` with this).
pub fn cross_entropy_chunks(t: usize) -> usize {
    t.div_ceil(CE_ROWS)
}

/// Masked cross-entropy over `(T, V)` logits with next-token targets.
///
/// Writes `dlogits` in place (every element), accumulates per-chunk f64
/// loss partials in `loss_parts` (length [`cross_entropy_chunks`]`(t)`),
/// and returns `loss = Σ_t mask_t · nll_t / max(Σ mask, 1)` — the packed
/// `loss_mask` zeroes padding slots and each sequence's final token, so
/// training never predicts across a packed boundary.
pub fn cross_entropy_into(
    logits: &[f32],
    v: usize,
    targets: &[i32],
    mask: &[f32],
    threads: usize,
    dlogits: &mut [f32],
    loss_parts: &mut [f64],
) -> f32 {
    let denom = mask_denom(mask);
    let sum = cross_entropy_sum_into(logits, v, targets, mask, denom, threads, dlogits, loss_parts);
    (sum / denom as f64) as f32
}

/// The masked-CE normalizer: `max(Σ mask, 1)` — exposed so the chunked
/// path can normalize per-chunk sums by the whole batch's mask.
pub fn mask_denom(mask: &[f32]) -> f32 {
    mask.iter().sum::<f32>().max(1.0)
}

/// Masked cross-entropy with an **externally supplied** denominator:
/// `dlogits` is scaled by `mask/denom` and the return value is the
/// *unnormalized* `f64` sum `Σ_t mask_t · nll_t`.  The chunked path runs
/// this per chunk with the whole batch's [`mask_denom`] and divides the
/// cross-chunk total once, so chunked gradients match the monolithic
/// step's normalization exactly.
#[allow(clippy::too_many_arguments)]
pub fn cross_entropy_sum_into(
    logits: &[f32],
    v: usize,
    targets: &[i32],
    mask: &[f32],
    denom: f32,
    threads: usize,
    dlogits: &mut [f32],
    loss_parts: &mut [f64],
) -> f64 {
    let _sp = trace::span(Op::CrossEntropy);
    let t = targets.len();
    assert_eq!(logits.len(), t * v);
    assert_eq!(mask.len(), t);
    assert_eq!(dlogits.len(), t * v);
    assert_eq!(loss_parts.len(), cross_entropy_chunks(t));
    let threads = effective_threads(t * v * 8, threads);
    parallel_chunks2_mut(dlogits, CE_ROWS * v, loss_parts, 1, threads, |ci, dl, part| {
        let lo = ci * CE_ROWS;
        let hi = (lo + CE_ROWS).min(t);
        let mut loss = 0.0f64;
        for ti in lo..hi {
            let row = &logits[ti * v..(ti + 1) * v];
            let w = mask[ti];
            let max = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
            let sum: f32 = row.iter().map(|&x| (x - max).exp()).sum();
            let lse = max + sum.ln();
            let tgt = targets[ti] as usize;
            debug_assert!(tgt < v, "target {tgt} out of vocab {v}");
            if w > 0.0 {
                loss += (w * (lse - row[tgt])) as f64;
            }
            let drow = &mut dl[(ti - lo) * v..(ti - lo + 1) * v];
            let scale = w / denom;
            if scale != 0.0 {
                for (o, &x) in drow.iter_mut().zip(row) {
                    *o = scale * (x - max).exp() / sum;
                }
                drow[tgt] -= scale;
            } else {
                drow.iter_mut().for_each(|o| *o = 0.0);
            }
        }
        part[0] = loss;
    });
    loss_parts.iter().sum()
}

/// Masked cross-entropy; returns `(loss, dlogits)`.
pub fn cross_entropy(
    logits: &[f32],
    v: usize,
    targets: &[i32],
    mask: &[f32],
    threads: usize,
) -> (f32, Vec<f32>) {
    let t = targets.len();
    let mut dlogits = vec![0.0f32; t * v];
    let mut parts = vec![0.0f64; cross_entropy_chunks(t)];
    let loss = cross_entropy_into(logits, v, targets, mask, threads, &mut dlogits, &mut parts);
    (loss, dlogits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_variants_agree_with_reference() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // (2,3)
        let b = [1.0f32, 0.5, -1.0, 2.0, 0.0, 1.0]; // (3,2)
        let c = matmul(&a, 2, 3, &b, 2, 1);
        // row0: [1*1+2*(-1)+3*0, 1*.5+2*2+3*1] = [-1, 7.5]
        assert_eq!(c, vec![-1.0, 7.5, -1.0, 18.0]);

        // b^T is (2,3); matmul_nt(a, b_t) must equal matmul(a, b)
        let b_t = [1.0f32, -1.0, 0.0, 0.5, 2.0, 1.0];
        assert_eq!(matmul_nt(&a, 2, 3, &b_t, 2, 1), c);

        // a^T @ a via matmul_tn equals explicit transpose multiply
        let ata = matmul_tn(&a, 2, 3, &a, 3, 1);
        let a_t = [1.0f32, 4.0, 2.0, 5.0, 3.0, 6.0]; // (3,2)
        assert_eq!(ata, matmul(&a_t, 3, 2, &a, 3, 1));
    }

    #[test]
    fn matmul_parallel_matches_serial() {
        let m = 37;
        let k = 19;
        let n = 23;
        let a: Vec<f32> = (0..m * k).map(|i| ((i * 7 % 13) as f32) - 6.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| ((i * 5 % 11) as f32) - 5.0).collect();
        assert_eq!(matmul(&a, m, k, &b, n, 1), matmul(&a, m, k, &b, n, 8));
    }

    #[test]
    fn beta_accumulate_fuses_add() {
        let (m, k, n) = (9, 14, 6);
        let a: Vec<f32> = (0..m * k).map(|i| ((i * 3 % 7) as f32) - 3.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| ((i * 5 % 9) as f32) - 4.0).collect();
        let prior: Vec<f32> = (0..m * n).map(|i| i as f32 * 0.25).collect();
        let mut fused = prior.clone();
        matmul_into(&a, m, k, &b, n, 1.0, &mut fused, 1, &mut GemmScratch::new());
        let prod = matmul(&a, m, k, &b, n, 1);
        for ((f, p), q) in fused.iter().zip(&prior).zip(&prod) {
            assert!((f - (p + q)).abs() < 1e-5, "{f} vs {}", p + q);
        }
    }

    #[test]
    fn transpose_round_trips() {
        let (b, l, d) = (2, 5, 3);
        let x: Vec<f32> = (0..b * l * d).map(|i| i as f32).collect();
        let cm = to_channel_major(&x, b, l, d);
        assert_eq!(cm[0 * l + 1], x[1 * d]); // channel 0, t=1
        assert_eq!(to_token_major(&cm, b, d, l), x);
    }

    #[test]
    fn blocked_transpose_matches_reference_on_odd_shapes() {
        // shapes straddling the 32-wide tile edge
        let (b, l, d) = (2, 37, 33);
        let x: Vec<f32> = (0..b * l * d).map(|i| (i as f32).sin()).collect();
        let cm = to_channel_major(&x, b, l, d);
        for bi in 0..b {
            for t in 0..l {
                for c in 0..d {
                    assert_eq!(cm[bi * l * d + c * l + t], x[bi * l * d + t * d + c]);
                }
            }
        }
        assert_eq!(to_token_major(&cm, b, d, l), x);
    }

    #[test]
    fn rms_norm_normalizes_and_backward_matches_fd() {
        let d = 4;
        let x = vec![0.5f32, -1.0, 2.0, 0.25, 1.0, 1.0, -1.0, 3.0];
        let w = vec![1.0f32, 0.5, 2.0, -1.0];
        let eps = 1e-5;
        let (y, inv) = rms_norm_fwd(&x, d, &w, eps);
        // unit-ish rms after normalization (before w)
        let rms: f32 = (0..d).map(|i| (x[i] * inv[0]).powi(2)).sum::<f32>() / d as f32;
        assert!((rms - 1.0).abs() < 1e-3, "rms {rms}");

        // finite-difference check of dx against a scalar objective Σ y·g
        let g = vec![0.3f32, -0.2, 0.1, 0.7, -0.4, 0.25, 0.6, -0.1];
        let (dx, dw) = rms_norm_bwd(&x, d, &w, &inv, &g);
        let f = |x: &[f32], w: &[f32]| -> f32 {
            let (y, _) = rms_norm_fwd(x, d, w, eps);
            y.iter().zip(&g).map(|(a, b)| a * b).sum()
        };
        let h = 1e-3;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp[i] += h;
            let mut xm = x.clone();
            xm[i] -= h;
            let fd = (f(&xp, &w) - f(&xm, &w)) / (2.0 * h);
            assert!((fd - dx[i]).abs() < 2e-3, "dx[{i}]: fd {fd} an {}", dx[i]);
        }
        for i in 0..d {
            let mut wp = w.clone();
            wp[i] += h;
            let mut wm = w.clone();
            wm[i] -= h;
            let fd = (f(&x, &wp) - f(&x, &wm)) / (2.0 * h);
            assert!((fd - dw[i]).abs() < 2e-3, "dw[{i}]: fd {fd} an {}", dw[i]);
        }
        let _ = y;
    }

    #[test]
    fn activations_sane() {
        assert!((silu(0.0)).abs() < 1e-9);
        assert!((softplus(0.0) - (2.0f32).ln()).abs() < 1e-6);
        assert!((softplus(30.0) - 30.0).abs() < 1e-4);
        assert!(softplus(-30.0) > 0.0 && softplus(-30.0) < 1e-9);
        // dsilu via finite differences
        for x in [-2.0f32, -0.5, 0.0, 0.7, 3.0] {
            let h = 1e-3;
            let fd = (silu(x + h) - silu(x - h)) / (2.0 * h);
            assert!((fd - dsilu(x)).abs() < 1e-3, "x={x}");
        }
    }

    #[test]
    fn cross_entropy_uniform_logits() {
        let v = 8;
        let t = 4;
        let logits = vec![0.0f32; t * v];
        let targets = vec![1i32, 2, 3, 4];
        let mask = vec![1.0f32, 1.0, 0.0, 1.0];
        let (loss, dl) = cross_entropy(&logits, v, &targets, &mask, 1);
        assert!((loss - (v as f32).ln()).abs() < 1e-5);
        // masked-out token contributes no gradient
        assert!(dl[2 * v..3 * v].iter().all(|&x| x == 0.0));
        // gradient rows sum to ~0
        let s: f32 = dl[..v].iter().sum();
        assert!(s.abs() < 1e-6);
    }

    #[test]
    fn cross_entropy_overwrites_stale_dlogits() {
        // the _into form must fully overwrite arena-recycled buffers,
        // including masked-out rows
        let v = 5;
        let t = 3;
        let logits: Vec<f32> = (0..t * v).map(|i| (i as f32) * 0.1).collect();
        let targets = vec![1i32, 2, 3];
        let mask = vec![1.0f32, 0.0, 1.0];
        let mut stale = vec![9.9f32; t * v];
        let mut parts = vec![0.0f64; cross_entropy_chunks(t)];
        let l1 = cross_entropy_into(&logits, v, &targets, &mask, 1, &mut stale, &mut parts);
        let (l2, fresh) = cross_entropy(&logits, v, &targets, &mask, 1);
        assert_eq!(l1, l2);
        assert_eq!(stale, fresh);
    }

    #[test]
    fn cross_entropy_gradient_matches_fd() {
        let v = 5;
        let t = 3;
        let mut logits: Vec<f32> = (0..t * v).map(|i| ((i * 13 % 7) as f32) * 0.3 - 1.0).collect();
        let targets = vec![4i32, 0, 2];
        let mask = vec![1.0f32, 0.0, 1.0];
        let (_, dl) = cross_entropy(&logits, v, &targets, &mask, 1);
        let h = 1e-3;
        for i in 0..t * v {
            let old = logits[i];
            logits[i] = old + h;
            let (lp, _) = cross_entropy(&logits, v, &targets, &mask, 1);
            logits[i] = old - h;
            let (lm, _) = cross_entropy(&logits, v, &targets, &mask, 1);
            logits[i] = old;
            let fd = (lp - lm) / (2.0 * h);
            assert!((fd - dl[i]).abs() < 1e-3, "dl[{i}]: fd {fd} an {}", dl[i]);
        }
    }
}
