//! Host-side fused AdamW — mirrors `adamw_update` in
//! `python/compile/model.py` (same defaults, same decoupled weight decay
//! on matrices only) so native and PJRT training follow the same
//! optimizer trajectory.

use crate::runtime::ParamSpec;
use crate::tensor::Tensor;
use crate::util::trace::{self, Op};
use crate::Result;

use super::params;
use super::TrainState;

/// Optimizer hyperparameters (defaults match the AOT artifacts).
#[derive(Clone, Copy, Debug)]
pub struct AdamWConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamWConfig {
    fn default() -> Self {
        Self {
            lr: 3e-4,
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay: 0.1,
        }
    }
}

/// The per-parameter update kernel shared by [`apply`] and
/// [`apply_slices`] — one fused pass, no temporaries.
#[allow(clippy::too_many_arguments)]
fn update_param(
    opt: &AdamWConfig,
    wd: f32,
    b1c: f32,
    b2c: f32,
    p: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
) {
    for i in 0..p.len() {
        let gi = g[i];
        m[i] = opt.beta1 * m[i] + (1.0 - opt.beta1) * gi;
        v[i] = opt.beta2 * v[i] + (1.0 - opt.beta2) * gi * gi;
        let mut upd = (m[i] / b1c) / ((v[i] / b2c).sqrt() + opt.eps);
        upd += wd * p[i];
        p[i] -= opt.lr * upd;
    }
}

fn bias_corrections(opt: &AdamWConfig, state: &TrainState) -> (f32, f32) {
    let step = state.step as f32 + 1.0;
    (1.0 - opt.beta1.powf(step), 1.0 - opt.beta2.powf(step))
}

/// Apply one AdamW update in place.  `step` inside is 1-based
/// (`state.step + 1`), matching the fused artifact's convention; the
/// caller advances `state.step` afterwards.
pub fn apply(
    opt: &AdamWConfig,
    specs: &[ParamSpec],
    state: &mut TrainState,
    grads: &[Tensor],
) -> Result<()> {
    let _sp = trace::span(Op::AdamW);
    anyhow::ensure!(
        specs.len() == state.params.len() && grads.len() == state.params.len(),
        "adamw arity: {} specs, {} params, {} grads",
        specs.len(),
        state.params.len(),
        grads.len()
    );
    let (b1c, b2c) = bias_corrections(opt, state);
    for (((spec, pt), mt), (vt, gt)) in specs
        .iter()
        .zip(state.params.iter_mut())
        .zip(state.m.iter_mut())
        .zip(state.v.iter_mut().zip(grads.iter()))
    {
        anyhow::ensure!(
            pt.shape() == gt.shape(),
            "adamw shape mismatch on {}: {:?} vs {:?}",
            spec.name,
            pt.shape(),
            gt.shape()
        );
        let wd = if params::decays(&spec.name) {
            opt.weight_decay
        } else {
            0.0
        };
        update_param(opt, wd, b1c, b2c, pt.data_mut(), mt.data_mut(), vt.data_mut(), gt.data());
    }
    Ok(())
}

/// [`apply`] over raw gradient buffers — the fused-train-step path: no
/// tensor wrapping, no allocation.
pub fn apply_slices(
    opt: &AdamWConfig,
    specs: &[ParamSpec],
    state: &mut TrainState,
    grads: &[Vec<f32>],
) -> Result<()> {
    let _sp = trace::span(Op::AdamW);
    anyhow::ensure!(
        specs.len() == state.params.len() && grads.len() == state.params.len(),
        "adamw arity: {} specs, {} params, {} grads",
        specs.len(),
        state.params.len(),
        grads.len()
    );
    let (b1c, b2c) = bias_corrections(opt, state);
    for (((spec, pt), mt), (vt, g)) in specs
        .iter()
        .zip(state.params.iter_mut())
        .zip(state.m.iter_mut())
        .zip(state.v.iter_mut().zip(grads.iter()))
    {
        anyhow::ensure!(
            pt.len() == g.len(),
            "adamw size mismatch on {}: {} vs {}",
            spec.name,
            pt.len(),
            g.len()
        );
        let wd = if params::decays(&spec.name) {
            opt.weight_decay
        } else {
            0.0
        };
        update_param(opt, wd, b1c, b2c, pt.data_mut(), mt.data_mut(), vt.data_mut(), g);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn tiny_state() -> (Vec<ParamSpec>, TrainState) {
        let specs = vec![
            ParamSpec {
                name: "embedding".to_string(),
                shape: vec![2, 2],
            },
            ParamSpec {
                name: "layers.0.conv_b".to_string(),
                shape: vec![3],
            },
        ];
        let params = vec![Tensor::full(&[2, 2], 1.0), Tensor::full(&[3], 1.0)];
        let zeros: Vec<Tensor> = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        (
            specs,
            TrainState {
                m: zeros.clone(),
                v: zeros,
                params,
                step: 0,
            },
        )
    }

    #[test]
    fn moves_against_gradient_and_decays_matrices() {
        let (specs, mut state) = tiny_state();
        let grads = vec![Tensor::full(&[2, 2], 1.0), Tensor::full(&[3], 1.0)];
        let opt = AdamWConfig::default();
        apply(&opt, &specs, &mut state, &grads).unwrap();
        // both move down (positive gradient); the decayed matrix moves more
        let decayed = state.params[0].data()[0];
        let plain = state.params[1].data()[0];
        assert!(decayed < 1.0 && plain < 1.0);
        assert!(decayed < plain, "decay should shrink the matrix more");
        // bias-corrected first step ≈ lr * (1 + wd) for the matrix
        let expect = 1.0 - opt.lr * (1.0 + opt.weight_decay);
        assert!((decayed - expect).abs() < 1e-4, "{decayed} vs {expect}");
    }

    #[test]
    fn apply_slices_matches_apply() {
        let (specs, mut s1) = tiny_state();
        let mut s2 = TrainState {
            params: s1.params.clone(),
            m: s1.m.clone(),
            v: s1.v.clone(),
            step: s1.step,
        };
        let grads = vec![Tensor::full(&[2, 2], 0.3), Tensor::full(&[3], -0.7)];
        let raw: Vec<Vec<f32>> = grads.iter().map(|g| g.data().to_vec()).collect();
        let opt = AdamWConfig::default();
        apply(&opt, &specs, &mut s1, &grads).unwrap();
        apply_slices(&opt, &specs, &mut s2, &raw).unwrap();
        for (a, b) in s1.params.iter().zip(&s2.params) {
            assert_eq!(a.data(), b.data());
        }
        for (a, b) in s1.m.iter().zip(&s2.m) {
            assert_eq!(a.data(), b.data());
        }
    }

    #[test]
    fn rejects_shape_mismatch() {
        let (specs, mut state) = tiny_state();
        let grads = vec![Tensor::full(&[2, 2], 1.0), Tensor::full(&[4], 1.0)];
        assert!(apply(&AdamWConfig::default(), &specs, &mut state, &grads).is_err());
    }

    #[test]
    fn state_specs_align_with_model_params() {
        // the canonical spec list drives decay decisions; spot check it
        let cfg = ModelConfig::tiny();
        let specs = params::specs(&cfg);
        assert!(specs.iter().any(|s| params::decays(&s.name)));
        assert!(specs.iter().any(|s| !params::decays(&s.name)));
    }
}
