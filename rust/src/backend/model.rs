//! The packed Mamba LM, natively: embedding → N gated Mamba blocks
//! (packed conv1d + packed selective scan) → RMSNorm → tied-embedding
//! head, with masked cross-entropy and a full analytic backward pass.
//!
//! Faithful to `python/compile/model.py`: the same parameter shapes, the
//! same block wiring, and the same packed-operator semantics — every
//! sequence-wise op takes `position_indices` so packed neighbours never
//! exchange state (the numerics were cross-checked against the reference
//! oracles by finite differences; `tests/native_backend.rs` asserts the
//! PUI invariant end-to-end).
//!
//! Activations flow token-major `(T, ·)` through the GEMMs and
//! channel-major `(B, D, L)` through the sequence-wise kernels, with
//! explicit transposes at the boundaries (see `kernels`).
//!
//! **Allocation discipline:** every buffer — activations, backward
//! caches, temporaries — is taken from the [`ModelWorkspace`]'s
//! [`StepArena`] and returned when dead, weight-gradient GEMMs fuse
//! `G += Xᵀ·dY` via the micro-kernel's beta-accumulate, and the layer-
//! cache list reuses its spine across steps.  After the first (warmup)
//! step, [`loss_and_grads_into`] performs zero heap allocations
//! (`tests/zero_alloc.rs` pins this with a counting allocator).

use crate::config::ModelConfig;
use crate::runtime::ParamSpec;
use crate::tensor::Tensor;
use crate::util::trace::{self, Op};

use super::arena::StepArena;
use super::kernels::{self, Dims, SsmGradsMut};
use super::ops;
use super::params::{self, slot};

const NORM_EPS: f32 = 1e-5;

/// Per-layer activations the backward pass consumes (all arena-owned).
struct LayerCache {
    /// block input `(T, d)`
    u: Vec<f32>,
    /// RMSNorm 1/rms per token `(T,)`
    inv: Vec<f32>,
    /// normed input `(T, d)`
    un: Vec<f32>,
    /// conv input, channel-major `(B, di, L)`
    xlin_cm: Vec<f32>,
    /// gate branch `(T, di)`
    z: Vec<f32>,
    /// conv output pre-silu, channel-major
    xc_cm: Vec<f32>,
    /// conv output post-silu (scan input), channel-major
    xs_cm: Vec<f32>,
    /// same, token-major `(T, di)`
    xs_tm: Vec<f32>,
    /// low-rank dt input `(T, r)`
    dt_low: Vec<f32>,
    /// selective B `(T, n)`
    bm: Vec<f32>,
    /// selective C `(T, n)`
    cm: Vec<f32>,
    /// dt before softplus `(T, di)`
    dt_pre: Vec<f32>,
    /// dt after softplus, channel-major
    dt_cm: Vec<f32>,
    /// scan state history `(B, di, L, n)`
    hist: Vec<f32>,
    /// masked decay `Ā` `(B, di, L, n)`
    am: Vec<f32>,
    /// scan output token-major `(T, di)`
    y_tm: Vec<f32>,
    /// gated output `y · silu(z)` `(T, di)`
    yz: Vec<f32>,
}

fn release_layer(c: LayerCache, arena: &mut StepArena) {
    let LayerCache {
        u,
        inv,
        un,
        xlin_cm,
        z,
        xc_cm,
        xs_cm,
        xs_tm,
        dt_low,
        bm,
        cm,
        dt_pre,
        dt_cm,
        hist,
        am,
        y_tm,
        yz,
    } = c;
    for v in [
        u, inv, un, xlin_cm, z, xc_cm, xs_cm, xs_tm, dt_low, bm, cm, dt_pre, dt_cm, hist, am,
        y_tm, yz,
    ] {
        arena.put(v);
    }
}

/// Reusable per-backend state for the model's forward/backward: the
/// buffer arena, the layer-cache spine, and the chunked step's per-chunk
/// spines + carry-state pool (every `Vec` capacity survives across
/// steps, so steady-state steps — monolithic *and* chunked — never touch
/// the heap).
#[derive(Default)]
pub struct ModelWorkspace {
    pub arena: StepArena,
    layers: Vec<LayerCache>,
    /// chunked step: per-chunk head caches (spine reused across steps)
    chunk_heads: Vec<ForwardCache>,
    /// chunked step: per-chunk carry-in states awaiting the backward
    chunk_states: Vec<ChunkState>,
    /// chunked step: per-chunk filled layer-cache spines
    chunk_layers: Vec<Vec<LayerCache>>,
    /// empty layer-cache spines (capacity kept) for the next chunk
    spare_layer_spines: Vec<Vec<LayerCache>>,
    /// pooled `ChunkState`s (spines + buffers) for the chunked step
    free_chunk_states: Vec<ChunkState>,
    /// multi-stream gather scratch: per-chunk lane-major token planes
    gather_tokens: Vec<i32>,
    gather_targets: Vec<i32>,
    gather_pos: Vec<i32>,
    /// multi-stream gather scratch: per-chunk lane-major loss mask
    gather_mask: Vec<f32>,
}

impl ModelWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size the scratch whose growth would otherwise happen inside
    /// the hot step (the cross-entropy `f64` partials) for a step over
    /// `t` tokens.  Backends call this in their warmup/ensure phase —
    /// next to the gradient-buffer sizing — so the timed step body never
    /// resizes it (`tests/zero_alloc.rs` interleaves two batch lengths
    /// to pin this).
    pub fn ensure_scratch(&mut self, t: usize) {
        let chunks = ops::cross_entropy_chunks(t);
        if self.arena.f64_scratch.len() < chunks {
            self.arena.f64_scratch.resize(chunks, 0.0);
        }
    }

    /// Pre-size the multi-stream gather scratch for chunks of
    /// `streams · chunk_len` slots (ensure phase, like
    /// [`ensure_scratch`](Self::ensure_scratch)) so the chunked step
    /// body never grows it.
    pub fn ensure_chunk_gather(&mut self, streams: usize, chunk_len: usize) {
        // clear first (the buffers may still hold the previous step's
        // final gather): with len 0, `reserve(n)` guarantees capacity
        // ≥ n and is a no-op once warm
        let n = streams * chunk_len;
        self.gather_tokens.clear();
        self.gather_tokens.reserve(n);
        self.gather_targets.clear();
        self.gather_targets.reserve(n);
        self.gather_pos.clear();
        self.gather_pos.reserve(n);
        self.gather_mask.clear();
        self.gather_mask.reserve(n);
    }

    /// A pooled [`ChunkState`] with `lanes` carry lanes for `cfg`
    /// (`zeroed` = stream-start semantics; otherwise contents are
    /// unspecified and must be fully overwritten).  Pool misses fall
    /// back to the arena; stale-geometry pool entries are recycled.
    pub fn take_chunk_state(&mut self, cfg: &ModelConfig, lanes: usize, zeroed: bool) -> ChunkState {
        while let Some(mut cs) = self.free_chunk_states.pop() {
            if cs.fits(cfg, lanes) {
                if zeroed {
                    for v in cs.h.iter_mut().chain(cs.tail.iter_mut()) {
                        v.iter_mut().for_each(|x| *x = 0.0);
                    }
                }
                return cs;
            }
            cs.release(&mut self.arena);
        }
        if zeroed {
            ChunkState::zeroed(cfg, lanes, &mut self.arena)
        } else {
            ChunkState::uninit(cfg, lanes, &mut self.arena)
        }
    }

    /// Return a [`ChunkState`] (buffers *and* spine) to the pool.
    pub fn recycle_chunk_state(&mut self, cs: ChunkState) {
        self.free_chunk_states.push(cs);
    }
}

/// Cross-chunk carry for chunked/stateful execution (paper §5): per
/// layer, the SSM state at the previous chunk's final slot
/// (`rows · d_inner · d_state`) and the final `d_conv - 1` conv inputs
/// (`rows · d_inner · (d_conv - 1)`) — a constant-size state per stream
/// row, independent of sequence length.  Buffers are recycled through
/// the [`StepArena`]; reused as-is for the *adjoint* carry (`h` ↦ dL/dh
/// of the carry state, `tail` ↦ dL/d(tail)) in the chunked backward.
/// `Default` is the empty placeholder (no layers) for `std::mem::take`.
#[derive(Default)]
pub struct ChunkState {
    /// per layer: SSM carry, `(rows, d_inner, d_state)` lane-major
    pub h: Vec<Vec<f32>>,
    /// per layer: conv input tail, `(rows, d_inner, d_conv - 1)` lane-major
    pub tail: Vec<Vec<f32>>,
}

impl ChunkState {
    /// Zeroed carry (a stream start) for `rows` rows, arena-recycled.
    pub fn zeroed(cfg: &ModelConfig, rows: usize, arena: &mut StepArena) -> ChunkState {
        let (di, n, wl) = (cfg.d_inner(), cfg.d_state, cfg.d_conv);
        ChunkState {
            h: (0..cfg.n_layers)
                .map(|_| arena.take_zeroed(rows * di * n))
                .collect(),
            tail: (0..cfg.n_layers)
                .map(|_| arena.take_zeroed(rows * di * (wl - 1)))
                .collect(),
        }
    }

    /// Carry buffers with unspecified contents — for carry-*out* slots
    /// that the kernels fully overwrite.
    pub fn uninit(cfg: &ModelConfig, rows: usize, arena: &mut StepArena) -> ChunkState {
        let (di, n, wl) = (cfg.d_inner(), cfg.d_state, cfg.d_conv);
        ChunkState {
            h: (0..cfg.n_layers).map(|_| arena.take(rows * di * n)).collect(),
            tail: (0..cfg.n_layers)
                .map(|_| arena.take(rows * di * (wl - 1)))
                .collect(),
        }
    }

    /// Whether this carry matches `cfg`'s shape for `rows` stream rows.
    pub fn fits(&self, cfg: &ModelConfig, rows: usize) -> bool {
        let (di, n, wl) = (cfg.d_inner(), cfg.d_state, cfg.d_conv);
        self.h.len() == cfg.n_layers
            && self.tail.len() == cfg.n_layers
            && self.h.iter().all(|v| v.len() == rows * di * n)
            && self.tail.iter().all(|v| v.len() == rows * di * (wl - 1))
    }

    /// Return every buffer to the arena.
    pub fn release(self, arena: &mut StepArena) {
        arena.put_all(self.h);
        arena.put_all(self.tail);
    }
}

/// Bytes of one chunk's forward caches — the 17 per-layer [`LayerCache`]
/// buffers plus the head [`ForwardCache`] — for a `(streams, clen)`
/// chunk.  This is the unit the cached chunked step keeps **live per
/// chunk** across the whole backward sweep, and the recomputed step
/// keeps live exactly once; transient backward scratch (common to both
/// modes and O(one layer)) is excluded.  The budget sizing in
/// `backend::native` compares `n_chunks ×` this against the configured
/// `--mem-budget`.
pub fn chunk_cache_bytes(cfg: &ModelConfig, streams: usize, clen: usize) -> usize {
    let (d, di, n, r, v) = (
        cfg.d_model,
        cfg.d_inner(),
        cfg.d_state,
        cfg.dt_rank(),
        cfg.vocab_size,
    );
    let t = streams * clen;
    // LayerCache: u + un (t·d each), inv (t), nine (t·di) planes
    // (xlin_cm, z, xc_cm, xs_cm, xs_tm, dt_pre, dt_cm, y_tm, yz),
    // dt_low (t·r), bm + cm (t·n each), hist + am (t·di·n each)
    let per_layer = t * (2 * d + 1 + 9 * di + r + 2 * n + 2 * di * n);
    // ForwardCache: logits (t·v), h_pre + hf (t·d each), invf (t)
    let head = t * (v + 2 * d + 1);
    (cfg.n_layers * per_layer + head) * std::mem::size_of::<f32>()
}

/// Bytes of one per-stream carry [`ChunkState`] (scan state `h` + conv
/// `tail` per layer) — the constant-size checkpoint that recompute mode
/// keeps per chunk instead of the full caches.
pub fn chunk_state_bytes(cfg: &ModelConfig, streams: usize) -> usize {
    let (di, n, wl) = (cfg.d_inner(), cfg.d_state, cfg.d_conv);
    cfg.n_layers * streams * di * (n + wl - 1) * std::mem::size_of::<f32>()
}

/// Head-side activations of one forward pass (layer caches live in the
/// workspace until consumed by the backward or released).
pub struct ForwardCache {
    /// `(T, vocab)` token logits
    pub logits: Vec<f32>,
    /// pre-final-norm hidden `(T, d)`
    h_pre: Vec<f32>,
    /// post-final-norm hidden `(T, d)`
    hf: Vec<f32>,
    invf: Vec<f32>,
}

fn add_into(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (a, b) in dst.iter_mut().zip(src) {
        *a += *b;
    }
}

/// Full forward pass, caching everything the backward needs in `ws`.
/// With `carry`, the sequence-wise kernels run their §5 carry variants:
/// layer `li` reads `carry.0.h[li]`/`carry.0.tail[li]` and writes the
/// chunk's outgoing state into `carry.1`.
#[allow(clippy::too_many_arguments)]
fn forward_impl(
    cfg: &ModelConfig,
    p: &[Tensor],
    tokens: &[i32],
    pos: &[i32],
    rows: usize,
    len: usize,
    threads: usize,
    ws: &mut ModelWorkspace,
    mut carry: Option<(&ChunkState, &mut ChunkState)>,
) -> ForwardCache {
    let (d, di, n, r, wl, v) = (
        cfg.d_model,
        cfg.d_inner(),
        cfg.d_state,
        cfg.dt_rank(),
        cfg.d_conv,
        cfg.vocab_size,
    );
    let t = rows * len;
    assert_eq!(tokens.len(), t, "token plane size");
    assert_eq!(pos.len(), t, "position plane size");
    assert_eq!(p.len(), params::count(cfg), "parameter count");
    assert!(ws.layers.is_empty(), "workspace holds a previous forward");
    let dims = Dims {
        b: rows,
        l: len,
        d: di,
        n,
    };

    // embedding lookup
    let emb = p[params::EMBEDDING].data();
    let mut h = ws.arena.take(t * d);
    for (ti, &tok) in tokens.iter().enumerate() {
        let tok = tok as usize;
        assert!(tok < v, "token {tok} outside vocab {v}");
        h[ti * d..(ti + 1) * d].copy_from_slice(&emb[tok * d..(tok + 1) * d]);
    }

    for li in 0..cfg.n_layers {
        let lp = |s: usize| p[params::layer_param(li, s)].data();

        let mut un = ws.arena.take(t * d);
        let mut inv = ws.arena.take(t);
        ops::rms_norm_fwd_into(&h, d, lp(slot::NORM_W), NORM_EPS, &mut un, &mut inv);
        let mut xz = ws.arena.take(t * 2 * di);
        {
            let _sp = trace::span(Op::GemmInProj);
            ops::matmul_into(
                &un,
                t,
                d,
                lp(slot::IN_PROJ),
                2 * di,
                0.0,
                &mut xz,
                threads,
                &mut ws.arena.gemm,
            );
        }
        let mut xlin = ws.arena.take(t * di);
        let mut z = ws.arena.take(t * di);
        for ti in 0..t {
            xlin[ti * di..(ti + 1) * di].copy_from_slice(&xz[ti * 2 * di..ti * 2 * di + di]);
            z[ti * di..(ti + 1) * di].copy_from_slice(&xz[ti * 2 * di + di..(ti + 1) * 2 * di]);
        }
        ws.arena.put(xz);

        // sequence-wise op #1: packed causal conv (state reset via pos)
        let mut xlin_cm = ws.arena.take(t * di);
        ops::to_channel_major_into(&xlin, rows, len, di, &mut xlin_cm);
        ws.arena.put(xlin);
        let mut xc_cm = ws.arena.take(t * di);
        if let Some((sin, sout)) = carry.as_mut() {
            kernels::conv1d_packed_fwd_carry_into(
                &xlin_cm,
                dims,
                lp(slot::CONV_W),
                wl,
                lp(slot::CONV_B),
                pos,
                &sin.tail[li],
                threads,
                &mut xc_cm,
                &mut sout.tail[li],
            );
        } else {
            kernels::conv1d_packed_fwd_into(
                &xlin_cm,
                dims,
                lp(slot::CONV_W),
                wl,
                lp(slot::CONV_B),
                pos,
                threads,
                &mut xc_cm,
            );
        }
        let mut xs_cm = ws.arena.take(t * di);
        for (o, &x) in xs_cm.iter_mut().zip(xc_cm.iter()) {
            *o = ops::silu(x);
        }
        let mut xs_tm = ws.arena.take(t * di);
        ops::to_token_major_into(&xs_cm, rows, di, len, &mut xs_tm);

        // selective projections
        let stride = r + 2 * n;
        let mut dbc = ws.arena.take(t * stride);
        {
            let _sp = trace::span(Op::GemmXProj);
            ops::matmul_into(
                &xs_tm,
                t,
                di,
                lp(slot::X_PROJ),
                stride,
                0.0,
                &mut dbc,
                threads,
                &mut ws.arena.gemm,
            );
        }
        let mut dt_low = ws.arena.take(t * r);
        let mut bm = ws.arena.take(t * n);
        let mut cm = ws.arena.take(t * n);
        for ti in 0..t {
            let row = &dbc[ti * stride..(ti + 1) * stride];
            dt_low[ti * r..(ti + 1) * r].copy_from_slice(&row[..r]);
            bm[ti * n..(ti + 1) * n].copy_from_slice(&row[r..r + n]);
            cm[ti * n..(ti + 1) * n].copy_from_slice(&row[r + n..]);
        }
        ws.arena.put(dbc);
        let mut dt_pre = ws.arena.take(t * di);
        {
            let _sp = trace::span(Op::GemmDtProj);
            ops::matmul_into(
                &dt_low,
                t,
                r,
                lp(slot::DT_PROJ),
                di,
                0.0,
                &mut dt_pre,
                threads,
                &mut ws.arena.gemm,
            );
        }
        let dt_bias = lp(slot::DT_BIAS);
        for ti in 0..t {
            let row = &mut dt_pre[ti * di..(ti + 1) * di];
            for (x, &b) in row.iter_mut().zip(dt_bias) {
                *x += b;
            }
        }
        let mut dt_tm = ws.arena.take(t * di);
        for (o, &x) in dt_tm.iter_mut().zip(dt_pre.iter()) {
            *o = ops::softplus(x);
        }
        let mut dt_cm = ws.arena.take(t * di);
        ops::to_channel_major_into(&dt_tm, rows, len, di, &mut dt_cm);
        ws.arena.put(dt_tm);

        // sequence-wise op #2: packed selective scan
        let mut a_neg = ws.arena.take(di * n);
        for (o, &x) in a_neg.iter_mut().zip(lp(slot::A_LOG)) {
            *o = -x.exp();
        }
        let mut y_cm = ws.arena.take(t * di);
        let mut hist = ws.arena.take(t * di * n);
        let mut am = ws.arena.take(t * di * n);
        if let Some((sin, sout)) = carry.as_mut() {
            kernels::ssm_packed_fwd_carry_into(
                &xs_cm,
                &dt_cm,
                &a_neg,
                &bm,
                &cm,
                lp(slot::D),
                pos,
                dims,
                &sin.h[li],
                threads,
                &mut y_cm,
                &mut hist,
                &mut am,
                &mut sout.h[li],
            );
        } else {
            kernels::ssm_packed_fwd_into(
                &xs_cm,
                &dt_cm,
                &a_neg,
                &bm,
                &cm,
                lp(slot::D),
                pos,
                dims,
                threads,
                &mut y_cm,
                &mut hist,
                &mut am,
            );
        }
        ws.arena.put(a_neg);
        let mut y_tm = ws.arena.take(t * di);
        ops::to_token_major_into(&y_cm, rows, di, len, &mut y_tm);
        ws.arena.put(y_cm);

        // gate + output projection + residual
        let mut yz = ws.arena.take(t * di);
        for i in 0..t * di {
            yz[i] = y_tm[i] * ops::silu(z[i]);
        }
        let mut out = ws.arena.take(t * d);
        {
            let _sp = trace::span(Op::GemmOutProj);
            ops::matmul_into(
                &yz,
                t,
                di,
                lp(slot::OUT_PROJ),
                d,
                0.0,
                &mut out,
                threads,
                &mut ws.arena.gemm,
            );
        }
        add_into(&mut out, &h); // residual into the fresh projection buffer
        let u = std::mem::replace(&mut h, out);

        ws.layers.push(LayerCache {
            u,
            inv,
            un,
            xlin_cm,
            z,
            xc_cm,
            xs_cm,
            xs_tm,
            dt_low,
            bm,
            cm,
            dt_pre,
            dt_cm,
            hist,
            am,
            y_tm,
            yz,
        });
    }

    let mut hf = ws.arena.take(t * d);
    let mut invf = ws.arena.take(t);
    ops::rms_norm_fwd_into(&h, d, p[params::norm_f(cfg)].data(), NORM_EPS, &mut hf, &mut invf);
    let mut logits = ws.arena.take(t * v);
    {
        let _sp = trace::span(Op::GemmHead);
        ops::matmul_nt_into(&hf, t, d, emb, v, 0.0, &mut logits, threads, &mut ws.arena.gemm);
    }
    ForwardCache {
        logits,
        h_pre: h,
        hf,
        invf,
    }
}

/// Full forward pass, caching everything the backward needs in `ws`.
#[allow(clippy::too_many_arguments)]
pub fn forward_cached(
    cfg: &ModelConfig,
    p: &[Tensor],
    tokens: &[i32],
    pos: &[i32],
    rows: usize,
    len: usize,
    threads: usize,
    ws: &mut ModelWorkspace,
) -> ForwardCache {
    forward_impl(cfg, p, tokens, pos, rows, len, threads, ws, None)
}

/// Forward over one chunk with §5 state carry: reads each layer's carry
/// from `state_in`, writes the outgoing carry into `state_out` (every
/// buffer fully overwritten).  Position indices decide whether the carry
/// flows: a chunk continuing a sequence has `pos[0] > 0`; a fresh start
/// (`pos[0] == 0`) masks the carried state out entirely, so junk carry
/// can never leak into a fresh sequence.
#[allow(clippy::too_many_arguments)]
pub fn forward_chunk_cached(
    cfg: &ModelConfig,
    p: &[Tensor],
    tokens: &[i32],
    pos: &[i32],
    rows: usize,
    len: usize,
    threads: usize,
    ws: &mut ModelWorkspace,
    state_in: &ChunkState,
    state_out: &mut ChunkState,
) -> ForwardCache {
    debug_assert!(state_in.fits(cfg, rows), "carry-in shape mismatch");
    debug_assert!(state_out.fits(cfg, rows), "carry-out shape mismatch");
    forward_impl(cfg, p, tokens, pos, rows, len, threads, ws, Some((state_in, state_out)))
}

/// Gather one chunk's lane-major plane: lane `s`'s slice
/// `[s·stream_tokens + off, s·stream_tokens + off + clen)` of `src`,
/// concatenated over lanes.  `dst` keeps its capacity (clear + extend),
/// so a warm buffer gathers without touching the heap.
// packlint: zero-alloc
fn gather_plane<T: Copy>(
    src: &[T],
    streams: usize,
    stream_tokens: usize,
    off: usize,
    clen: usize,
    dst: &mut Vec<T>,
) {
    let _sp = trace::span(Op::ChunkGather);
    dst.clear();
    for s in 0..streams {
        let base = s * stream_tokens + off;
        // packlint: allow(R1) -- gathers into a pooled workspace plane;
        // clear() keeps the capacity, so steady-state chunks don't grow it.
        dst.extend_from_slice(&src[base..base + clen]);
    }
}

/// Chunked/stateful forward over a whole packed batch (paper §5): the
/// `(rows, len)` plane is traversed as `streams` independent row-major
/// streams (stream `s` = rows `[s·rows/streams, (s+1)·rows/streams)`,
/// one carry lane each, processed side by side) in `chunk_len`-slot
/// steps, carrying per-layer SSM state and conv tails across chunk
/// boundaries — including across *row* boundaries within a stream, which
/// is what lets the streaming packer split sequences longer than
/// `pack_len` over consecutive rows (continuation position indices keep
/// the carry flowing; every fresh `pos == 0` start still isolates).
/// With `streams == 1` the whole batch is one stream (the PR-3
/// behavior).  Returns `(rows, len, vocab)` logits identical (within fp
/// reassociation) to the monolithic [`forward_logits`].
#[allow(clippy::too_many_arguments)]
pub fn forward_logits_chunked(
    cfg: &ModelConfig,
    p: &[Tensor],
    tokens: &[i32],
    pos: &[i32],
    rows: usize,
    len: usize,
    streams: usize,
    chunk_len: usize,
    threads: usize,
    ws: &mut ModelWorkspace,
) -> Tensor {
    assert!(chunk_len > 0, "chunk_len must be positive");
    assert!(
        streams >= 1 && rows % streams == 0,
        "rows {rows} must divide into {streams} streams"
    );
    let t_total = rows * len;
    let v = cfg.vocab_size;
    let stream_tokens = t_total / streams;
    // packlint: allow(R1) -- the logits tensor is this fn's return value
    // (caller-owned); the chunk loop below runs on pooled workspace spines.
    let mut out = vec![0.0f32; t_total * v];
    let mut g_tokens = std::mem::take(&mut ws.gather_tokens);
    let mut g_pos = std::mem::take(&mut ws.gather_pos);
    let mut cur = ws.take_chunk_state(cfg, streams, true);
    let mut off = 0;
    while off < stream_tokens {
        let clen = chunk_len.min(stream_tokens - off);
        let mut nxt = ws.take_chunk_state(cfg, streams, false);
        // lane-major gather (with one stream this is a plain sub-slice
        // copy — negligible next to the chunk's GEMMs, and alloc-free on
        // warm buffers)
        gather_plane(tokens, streams, stream_tokens, off, clen, &mut g_tokens);
        gather_plane(pos, streams, stream_tokens, off, clen, &mut g_pos);
        let fc = forward_chunk_cached(
            cfg,
            p,
            &g_tokens,
            &g_pos,
            streams,
            clen,
            threads,
            ws,
            &cur,
            &mut nxt,
        );
        // scatter the chunk's lane-major logits back to batch order
        for s in 0..streams {
            let dst = (s * stream_tokens + off) * v;
            out[dst..dst + clen * v].copy_from_slice(&fc.logits[s * clen * v..(s + 1) * clen * v]);
        }
        release_forward(fc, ws);
        ws.recycle_chunk_state(cur);
        cur = nxt;
        off += clen;
    }
    ws.recycle_chunk_state(cur);
    ws.gather_tokens = g_tokens;
    ws.gather_pos = g_pos;
    Tensor::new(&[rows, len, v], out)
}

/// Release a forward's buffers (head cache + the workspace's layer
/// caches) back to the arena without running a backward.
pub fn release_forward(fc: ForwardCache, ws: &mut ModelWorkspace) {
    let ForwardCache {
        logits,
        h_pre,
        hf,
        invf,
    } = fc;
    for v in [logits, h_pre, hf, invf] {
        ws.arena.put(v);
    }
    while let Some(c) = ws.layers.pop() {
        release_layer(c, &mut ws.arena);
    }
}

/// Forward returning only `(rows, len, vocab)` logits — the PUI surface.
#[allow(clippy::too_many_arguments)]
pub fn forward_logits(
    cfg: &ModelConfig,
    p: &[Tensor],
    tokens: &[i32],
    pos: &[i32],
    rows: usize,
    len: usize,
    threads: usize,
    ws: &mut ModelWorkspace,
) -> Tensor {
    let fc = forward_cached(cfg, p, tokens, pos, rows, len, threads, ws);
    // clone the logits instead of moving the arena's largest buffer into
    // the tensor: the eval path allocates anyway, and draining the `t·v`
    // buffer here would force the next train_step to re-allocate it
    let out = Tensor::new(&[rows, len, cfg.vocab_size], fc.logits.clone());
    release_forward(fc, ws);
    out
}

/// Two disjoint `&mut` gradient buffers (the conv backward accumulates
/// into weight and bias grads in one call).
fn two_muts(s: &mut [Vec<f32>], i: usize, j: usize) -> (&mut Vec<f32>, &mut Vec<f32>) {
    assert!(i < j && j < s.len());
    let (a, b) = s.split_at_mut(j);
    (&mut a[i], &mut b[0])
}

/// Masked-cross-entropy loss; **accumulates nothing outside `grads`** —
/// gradient buffers (canonical flat order, spec-sized) are zeroed here
/// and then filled via fused beta-accumulate GEMMs and kernel `_into`
/// calls.  Performs zero heap allocations once the workspace is warm.
#[allow(clippy::too_many_arguments)]
pub fn loss_and_grads_into(
    cfg: &ModelConfig,
    p: &[Tensor],
    tokens: &[i32],
    targets: &[i32],
    pos: &[i32],
    mask: &[f32],
    rows: usize,
    len: usize,
    threads: usize,
    ws: &mut ModelWorkspace,
    grads: &mut [Vec<f32>],
) -> f32 {
    assert_eq!(grads.len(), params::count(cfg), "gradient buffer count");
    for g in grads.iter_mut() {
        g.iter_mut().for_each(|x| *x = 0.0);
    }

    let fc = forward_cached(cfg, p, tokens, pos, rows, len, threads, ws);
    let denom = ops::mask_denom(mask);
    let (loss_sum, dh) = head_backward(cfg, p, fc, targets, mask, denom, threads, ws, grads);
    let mut layers = std::mem::take(&mut ws.layers);
    layers_backward(cfg, p, tokens, pos, rows, len, threads, ws, grads, &mut layers, dh, None);
    ws.layers = layers; // keep the spine's capacity for the next step
    (loss_sum / denom as f64) as f32
}

/// Head backward: masked CE (externally normalized by `denom`) against
/// the tied embedding, then the final RMSNorm.  Consumes `fc`, returns
/// the unnormalized `f64` loss sum and `dL/dh` of the last block's
/// output, `(T, d)` arena-owned.
#[allow(clippy::too_many_arguments)]
fn head_backward(
    cfg: &ModelConfig,
    p: &[Tensor],
    fc: ForwardCache,
    targets: &[i32],
    mask: &[f32],
    denom: f32,
    threads: usize,
    ws: &mut ModelWorkspace,
    grads: &mut [Vec<f32>],
) -> (f64, Vec<f32>) {
    let (d, v) = (cfg.d_model, cfg.vocab_size);
    let t = targets.len();
    let emb = p[params::EMBEDDING].data();
    let ce_chunks = ops::cross_entropy_chunks(t);
    if ws.arena.f64_scratch.len() < ce_chunks {
        // only direct callers with a cold workspace land here: backends
        // pre-size via `ModelWorkspace::ensure_scratch` before the step
        ws.arena.f64_scratch.resize(ce_chunks, 0.0);
    }
    let mut dlogits = ws.arena.take(t * v);
    let loss_sum = ops::cross_entropy_sum_into(
        &fc.logits,
        v,
        targets,
        mask,
        denom,
        threads,
        &mut dlogits,
        &mut ws.arena.f64_scratch[..ce_chunks],
    );
    let _sp_g = trace::span(Op::GemmBwd);
    ops::matmul_tn_into(
        &dlogits,
        t,
        v,
        &fc.hf,
        d,
        1.0,
        &mut grads[params::EMBEDDING],
        threads,
        &mut ws.arena.gemm,
    );
    let mut dhf = ws.arena.take(t * d);
    ops::matmul_into(&dlogits, t, v, emb, d, 0.0, &mut dhf, threads, &mut ws.arena.gemm);
    drop(_sp_g);
    ws.arena.put(dlogits);
    let mut dh = ws.arena.take(t * d);
    ops::rms_norm_bwd_into(
        &fc.h_pre,
        d,
        p[params::norm_f(cfg)].data(),
        &fc.invf,
        &dhf,
        &mut dh,
        &mut grads[params::norm_f(cfg)],
    );
    ws.arena.put(dhf);
    let ForwardCache {
        logits,
        h_pre,
        hf,
        invf,
    } = fc;
    for buf in [logits, h_pre, hf, invf] {
        ws.arena.put(buf);
    }
    (loss_sum, dh)
}

/// Backward through the Mamba blocks (reverse layer order), consuming
/// `layers` and accumulating into `grads`; finishes with the embedding
/// lookup gradient.  With `carry`, the sequence-wise backwards run their
/// §5 adjoint-carry variants: on entry `carry.1` holds the adjoint of
/// this chunk's carry-*out* (zeros for the stream's final chunk), on
/// exit it holds the adjoint of the carry-*in* (for the previous chunk);
/// `carry.0` is the carry-in state the forward consumed.
#[allow(clippy::too_many_arguments)]
fn layers_backward(
    cfg: &ModelConfig,
    p: &[Tensor],
    tokens: &[i32],
    pos: &[i32],
    rows: usize,
    len: usize,
    threads: usize,
    ws: &mut ModelWorkspace,
    grads: &mut [Vec<f32>],
    layers: &mut Vec<LayerCache>,
    dh_top: Vec<f32>,
    mut carry: Option<(&ChunkState, &mut ChunkState)>,
) {
    let (d, di, n, r, wl) = (
        cfg.d_model,
        cfg.d_inner(),
        cfg.d_state,
        cfg.dt_rank(),
        cfg.d_conv,
    );
    let t = rows * len;
    let dims = Dims {
        b: rows,
        l: len,
        d: di,
        n,
    };
    let mut dh = dh_top;
    while let Some(c) = layers.pop() {
        let li = layers.len();
        let lp = |s: usize| p[params::layer_param(li, s)].data();
        let gi = |s: usize| params::layer_param(li, s);
        let dout = dh; // grad of the block output, (T, d)

        // out = u + yz @ out_proj
        let mut dyz = ws.arena.take(t * di);
        let _sp_g = trace::span(Op::GemmBwd);
        ops::matmul_nt_into(
            &dout,
            t,
            d,
            lp(slot::OUT_PROJ),
            di,
            0.0,
            &mut dyz,
            threads,
            &mut ws.arena.gemm,
        );
        ops::matmul_tn_into(
            &c.yz,
            t,
            di,
            &dout,
            d,
            1.0,
            &mut grads[gi(slot::OUT_PROJ)],
            threads,
            &mut ws.arena.gemm,
        );
        drop(_sp_g);

        // yz = y · silu(z)
        let mut dy_tm = ws.arena.take(t * di);
        let mut dz = ws.arena.take(t * di);
        for i in 0..t * di {
            dy_tm[i] = dyz[i] * ops::silu(c.z[i]);
            dz[i] = dyz[i] * c.y_tm[i] * ops::dsilu(c.z[i]);
        }
        ws.arena.put(dyz);

        // packed selective scan backward
        let mut a_neg = ws.arena.take(di * n);
        for (o, &x) in a_neg.iter_mut().zip(lp(slot::A_LOG)) {
            *o = -x.exp();
        }
        let mut dy_cm = ws.arena.take(t * di);
        ops::to_channel_major_into(&dy_tm, rows, len, di, &mut dy_cm);
        ws.arena.put(dy_tm);
        let mut sdx = ws.arena.take(t * di);
        let mut sddt = ws.arena.take(t * di);
        let mut sda = ws.arena.take(di * n);
        let mut sdbm = ws.arena.take(t * n);
        let mut sdcm = ws.arena.take(t * n);
        let mut sdd = ws.arena.take(di);
        let mut gbuf = ws.arena.take(t * di * n);
        let mut colbuf = ws.arena.take(di * (n + 1));
        if let Some((sin, adj)) = carry.as_mut() {
            // adj.h[li] enters as dL/d(carry-out state) and is swapped
            // for dL/d(carry-in state) for the previous chunk's backward
            let mut dh0 = ws.arena.take(rows * di * n);
            kernels::ssm_packed_bwd_carry_into(
                &c.xs_cm,
                &c.dt_cm,
                &a_neg,
                &c.bm,
                &c.cm,
                lp(slot::D),
                &c.hist,
                &c.am,
                &dy_cm,
                dims,
                &sin.h[li],
                &adj.h[li],
                threads,
                SsmGradsMut {
                    dx: &mut sdx,
                    ddt: &mut sddt,
                    da: &mut sda,
                    dbm: &mut sdbm,
                    dcm: &mut sdcm,
                    dd: &mut sdd,
                },
                &mut dh0,
                &mut gbuf,
                &mut colbuf,
            );
            ws.arena.put(std::mem::replace(&mut adj.h[li], dh0));
        } else {
            kernels::ssm_packed_bwd_into(
                &c.xs_cm,
                &c.dt_cm,
                &a_neg,
                &c.bm,
                &c.cm,
                lp(slot::D),
                &c.hist,
                &c.am,
                &dy_cm,
                dims,
                threads,
                SsmGradsMut {
                    dx: &mut sdx,
                    ddt: &mut sddt,
                    da: &mut sda,
                    dbm: &mut sdbm,
                    dcm: &mut sdcm,
                    dd: &mut sdd,
                },
                &mut gbuf,
                &mut colbuf,
            );
        }
        ws.arena.put(gbuf);
        ws.arena.put(colbuf);
        ws.arena.put(dy_cm);
        {
            // A = -exp(A_log) ⇒ ∂A/∂A_log = A
            let g = &mut grads[gi(slot::A_LOG)];
            for i in 0..di * n {
                g[i] += sda[i] * a_neg[i];
            }
        }
        ws.arena.put(sda);
        ws.arena.put(a_neg);
        add_into(&mut grads[gi(slot::D)], &sdd);
        ws.arena.put(sdd);

        // dt = softplus(dt_low @ dt_proj + dt_bias)
        let mut ddt_tm = ws.arena.take(t * di);
        ops::to_token_major_into(&sddt, rows, di, len, &mut ddt_tm);
        ws.arena.put(sddt);
        let mut ddt_pre = ws.arena.take(t * di);
        for i in 0..t * di {
            ddt_pre[i] = ddt_tm[i] * ops::sigmoid(c.dt_pre[i]);
        }
        ws.arena.put(ddt_tm);
        {
            let g = &mut grads[gi(slot::DT_BIAS)];
            for ti in 0..t {
                let row = &ddt_pre[ti * di..(ti + 1) * di];
                for (a, &b) in g.iter_mut().zip(row) {
                    *a += b;
                }
            }
        }
        let _sp_g = trace::span(Op::GemmBwd);
        ops::matmul_tn_into(
            &c.dt_low,
            t,
            r,
            &ddt_pre,
            di,
            1.0,
            &mut grads[gi(slot::DT_PROJ)],
            threads,
            &mut ws.arena.gemm,
        );
        let mut ddt_low = ws.arena.take(t * r);
        ops::matmul_nt_into(
            &ddt_pre,
            t,
            di,
            lp(slot::DT_PROJ),
            r,
            0.0,
            &mut ddt_low,
            threads,
            &mut ws.arena.gemm,
        );
        drop(_sp_g);
        ws.arena.put(ddt_pre);

        // dbc = xs @ x_proj, split into (dt_low | B | C)
        let stride = r + 2 * n;
        let mut ddbc = ws.arena.take(t * stride);
        for ti in 0..t {
            ddbc[ti * stride..ti * stride + r].copy_from_slice(&ddt_low[ti * r..(ti + 1) * r]);
            ddbc[ti * stride + r..ti * stride + r + n]
                .copy_from_slice(&sdbm[ti * n..(ti + 1) * n]);
            ddbc[ti * stride + r + n..(ti + 1) * stride]
                .copy_from_slice(&sdcm[ti * n..(ti + 1) * n]);
        }
        ws.arena.put(ddt_low);
        ws.arena.put(sdbm);
        ws.arena.put(sdcm);
        let _sp_g = trace::span(Op::GemmBwd);
        ops::matmul_tn_into(
            &c.xs_tm,
            t,
            di,
            &ddbc,
            stride,
            1.0,
            &mut grads[gi(slot::X_PROJ)],
            threads,
            &mut ws.arena.gemm,
        );
        drop(_sp_g);
        // dxs = transpose(scan dx) + ddbc @ x_projᵀ, fused via beta=1
        let mut dxs_tm = ws.arena.take(t * di);
        ops::to_token_major_into(&sdx, rows, di, len, &mut dxs_tm);
        ws.arena.put(sdx);
        let _sp_g = trace::span(Op::GemmBwd);
        ops::matmul_nt_into(
            &ddbc,
            t,
            stride,
            lp(slot::X_PROJ),
            di,
            1.0,
            &mut dxs_tm,
            threads,
            &mut ws.arena.gemm,
        );
        drop(_sp_g);
        ws.arena.put(ddbc);

        // silu + packed conv backward
        let mut dxs_cm = ws.arena.take(t * di);
        ops::to_channel_major_into(&dxs_tm, rows, len, di, &mut dxs_cm);
        ws.arena.put(dxs_tm);
        let mut dxc_cm = ws.arena.take(t * di);
        for i in 0..t * di {
            dxc_cm[i] = dxs_cm[i] * ops::dsilu(c.xc_cm[i]);
        }
        ws.arena.put(dxs_cm);
        let mut dxlin_cm = ws.arena.take(t * di);
        let mut convcol = ws.arena.take(di * (wl + 1));
        if let Some((sin, adj)) = carry.as_mut() {
            // adj.tail[li] enters as dL/d(carry-out tail) and is swapped
            // for dL/d(carry-in tail)
            let mut dtail0 = ws.arena.take(rows * di * (wl - 1));
            let (dw_g, db_g) = two_muts(grads, gi(slot::CONV_W), gi(slot::CONV_B));
            kernels::conv1d_packed_bwd_carry_into(
                &c.xlin_cm,
                dims,
                lp(slot::CONV_W),
                wl,
                pos,
                &sin.tail[li],
                &dxc_cm,
                &adj.tail[li],
                threads,
                &mut dxlin_cm,
                dw_g,
                db_g,
                &mut dtail0,
                &mut convcol,
            );
            ws.arena.put(std::mem::replace(&mut adj.tail[li], dtail0));
        } else {
            let (dw_g, db_g) = two_muts(grads, gi(slot::CONV_W), gi(slot::CONV_B));
            kernels::conv1d_packed_bwd_into(
                &c.xlin_cm,
                dims,
                lp(slot::CONV_W),
                wl,
                pos,
                &dxc_cm,
                threads,
                &mut dxlin_cm,
                dw_g,
                db_g,
                &mut convcol,
            );
        }
        ws.arena.put(dxc_cm);
        ws.arena.put(convcol);
        let mut dxlin_tm = ws.arena.take(t * di);
        ops::to_token_major_into(&dxlin_cm, rows, di, len, &mut dxlin_tm);
        ws.arena.put(dxlin_cm);

        // xz = un @ in_proj, xz = (x | z)
        let mut dxz = ws.arena.take(t * 2 * di);
        for ti in 0..t {
            dxz[ti * 2 * di..ti * 2 * di + di]
                .copy_from_slice(&dxlin_tm[ti * di..(ti + 1) * di]);
            dxz[ti * 2 * di + di..(ti + 1) * 2 * di].copy_from_slice(&dz[ti * di..(ti + 1) * di]);
        }
        ws.arena.put(dxlin_tm);
        ws.arena.put(dz);
        let _sp_g = trace::span(Op::GemmBwd);
        ops::matmul_tn_into(
            &c.un,
            t,
            d,
            &dxz,
            2 * di,
            1.0,
            &mut grads[gi(slot::IN_PROJ)],
            threads,
            &mut ws.arena.gemm,
        );
        let mut dun = ws.arena.take(t * d);
        ops::matmul_nt_into(
            &dxz,
            t,
            2 * di,
            lp(slot::IN_PROJ),
            d,
            0.0,
            &mut dun,
            threads,
            &mut ws.arena.gemm,
        );
        drop(_sp_g);
        ws.arena.put(dxz);

        // RMSNorm backward + residual
        let mut dup = ws.arena.take(t * d);
        ops::rms_norm_bwd_into(
            &c.u,
            d,
            lp(slot::NORM_W),
            &c.inv,
            &dun,
            &mut dup,
            &mut grads[gi(slot::NORM_W)],
        );
        ws.arena.put(dun);
        add_into(&mut dup, &dout);
        ws.arena.put(dout);
        dh = dup;

        release_layer(c, &mut ws.arena);
    }

    // embedding lookup gradient
    {
        let g = &mut grads[params::EMBEDDING];
        for (ti, &tok) in tokens.iter().enumerate() {
            let dst = &mut g[tok as usize * d..(tok as usize + 1) * d];
            let src = &dh[ti * d..(ti + 1) * d];
            for (a, &b) in dst.iter_mut().zip(src) {
                *a += b;
            }
        }
    }
    ws.arena.put(dh);
    debug_assert_eq!(tokens.len(), t);
}

/// Rebuild one chunk's forward caches just-in-time for the reverse
/// sweep (recompute mode): re-runs the deterministic chunk forward from
/// the checkpointed carry-in, leaving the chunk's layer caches in
/// `ws.layers` exactly as the caching forward left them.  The carry-out
/// goes to pooled scratch and is recycled immediately — the backward
/// already holds the downstream chunk's carry-in.
// packlint: zero-alloc
#[allow(clippy::too_many_arguments)]
fn recompute_chunk_caches(
    cfg: &ModelConfig,
    p: &[Tensor],
    tokens: &[i32],
    pos: &[i32],
    streams: usize,
    clen: usize,
    threads: usize,
    ws: &mut ModelWorkspace,
    state_in: &ChunkState,
) -> ForwardCache {
    let mut scratch = ws.take_chunk_state(cfg, streams, false);
    let fc = forward_chunk_cached(
        cfg, p, tokens, pos, streams, clen, threads, ws, state_in, &mut scratch,
    );
    ws.recycle_chunk_state(scratch);
    fc
}

/// Chunked/stateful loss + gradients (paper §5), the training-side twin
/// of [`forward_logits_chunked`]: the `(rows, len)` batch is traversed
/// as `streams` independent row-major streams (one carry lane each,
/// processed side by side) in `chunk_len`-slot pieces, forward carrying
/// per-layer `(h, conv tail)` state, backward carrying the matching
/// adjoints in reverse — full BPTT across every chunk of every stream,
/// so the gradients match the monolithic [`loss_and_grads_into`] up to
/// fp reassociation.  Chunk loss sums are accumulated in `f64`.
///
/// `denom` is the cross-entropy normalizer.  For a whole batch that is
/// [`ops::mask_denom`] of its own mask; a data-parallel worker running a
/// row-split sub-batch passes the *full* batch's denominator instead, so
/// summing worker losses and gradients reproduces the single-worker
/// step exactly (§4 chunk-aware dp).
///
/// `carry`, when provided, is the per-stream start state (the previous
/// step's stream-end state for truncated-BPTT continuation across
/// batches; treated as a constant in the backward) and is replaced with
/// this batch's stream-end state on return.  Its lane count must equal
/// `streams`.  `None` starts from zeros and discards the end state.
///
/// Every per-chunk spine (head caches, layer caches, carry states) and
/// the multi-stream gather scratch is recycled through `ws`, so the
/// steady-state chunked step performs zero heap allocations
/// (`tests/zero_alloc.rs`).
///
/// With `recompute`, the forward keeps only each chunk's constant-size
/// carry-in [`ChunkState`] (the `(D,N)` scan state + `(D,W-1)` conv
/// tail) and releases the activations immediately; the reverse sweep
/// rebuilds each chunk's caches just-in-time via
/// [`recompute_chunk_caches`].  Live activation memory is then
/// O(chunk_len) regardless of stream length, and because the kernels
/// are deterministic the recomputed gradients (and the loss) are
/// bitwise identical to the cache-everything path.
#[allow(clippy::too_many_arguments)]
pub fn loss_and_grads_chunked_into(
    cfg: &ModelConfig,
    p: &[Tensor],
    tokens: &[i32],
    targets: &[i32],
    pos: &[i32],
    mask: &[f32],
    rows: usize,
    len: usize,
    streams: usize,
    chunk_len: usize,
    threads: usize,
    ws: &mut ModelWorkspace,
    grads: &mut [Vec<f32>],
    denom: f32,
    mut carry: Option<&mut ChunkState>,
    recompute: bool,
) -> f32 {
    assert!(chunk_len > 0, "chunk_len must be positive");
    assert!(
        streams >= 1 && rows % streams == 0,
        "rows {rows} must divide into {streams} streams"
    );
    assert!(denom > 0.0, "cross-entropy denom must be positive");
    assert_eq!(grads.len(), params::count(cfg), "gradient buffer count");
    for g in grads.iter_mut() {
        g.iter_mut().for_each(|x| *x = 0.0);
    }
    let t_total = rows * len;
    assert_eq!(tokens.len(), t_total);
    assert_eq!(targets.len(), t_total);
    assert_eq!(pos.len(), t_total);
    assert_eq!(mask.len(), t_total);
    let stream_tokens = t_total / streams;
    let n_chunks = stream_tokens.div_ceil(chunk_len);

    // Persistent spines out of the workspace: their capacities survive
    // across steps, so the steady-state step never grows them.
    let mut heads = std::mem::take(&mut ws.chunk_heads);
    let mut states = std::mem::take(&mut ws.chunk_states);
    let mut filled = std::mem::take(&mut ws.chunk_layers);
    let mut spare = std::mem::take(&mut ws.spare_layer_spines);
    debug_assert!(heads.is_empty() && states.is_empty() && filled.is_empty());
    if ws.layers.capacity() == 0 {
        if let Some(s) = spare.pop() {
            ws.layers = s;
        }
    }
    let mut g_tokens = std::mem::take(&mut ws.gather_tokens);
    let mut g_targets = std::mem::take(&mut ws.gather_targets);
    let mut g_pos = std::mem::take(&mut ws.gather_pos);
    let mut g_mask = std::mem::take(&mut ws.gather_mask);

    // Forward over the streams, keeping every chunk's layer caches, head
    // cache, and carry-in state for the reverse sweep.
    let mut cur = match carry.as_mut() {
        Some(c) if c.fits(cfg, streams) => std::mem::take(*c),
        Some(_) => panic!("chunk carry shape does not match model/stream geometry"),
        None => ws.take_chunk_state(cfg, streams, true),
    };
    let mut off = 0;
    while off < stream_tokens {
        let clen = chunk_len.min(stream_tokens - off);
        let mut nxt = ws.take_chunk_state(cfg, streams, false);
        // lane-major gather (with one stream: a plain sub-slice copy,
        // alloc-free on warm buffers)
        gather_plane(tokens, streams, stream_tokens, off, clen, &mut g_tokens);
        gather_plane(pos, streams, stream_tokens, off, clen, &mut g_pos);
        let fc = forward_chunk_cached(
            cfg,
            p,
            &g_tokens,
            &g_pos,
            streams,
            clen,
            threads,
            ws,
            &cur,
            &mut nxt,
        );
        if recompute {
            // bounded-memory mode: drop this chunk's activations now —
            // the reverse sweep rebuilds them from the checkpointed
            // carry-in state (constant live activation set).
            release_forward(fc, ws);
        } else {
            // packlint: allow(R1) -- push into the pooled chunk-head spine;
            // capacity survives in ModelWorkspace across steps.
            heads.push(fc);
            // packlint: allow(R1) -- pooled layer-cache spine, same discipline.
            filled.push(std::mem::replace(
                &mut ws.layers,
                spare.pop().unwrap_or_default(),
            ));
        }
        // packlint: allow(R1) -- pooled carry-state spine, same discipline.
        states.push(cur);
        cur = nxt;
        off += clen;
    }
    match carry {
        Some(c) => *c = cur, // per-stream end state for the next batch
        None => ws.recycle_chunk_state(cur),
    }

    // Backward over chunks in reverse; `adj` holds each layer's adjoint
    // of the current chunk's carry-out (zeros for the final chunk).
    let mut adj = ws.take_chunk_state(cfg, streams, true);
    let mut loss_sum = 0.0f64;
    for k in (0..n_chunks).rev() {
        let off = k * chunk_len;
        let clen = chunk_len.min(stream_tokens - off);
        let sin = states.pop().expect("carry-in per chunk");
        gather_plane(tokens, streams, stream_tokens, off, clen, &mut g_tokens);
        gather_plane(targets, streams, stream_tokens, off, clen, &mut g_targets);
        gather_plane(pos, streams, stream_tokens, off, clen, &mut g_pos);
        gather_plane(mask, streams, stream_tokens, off, clen, &mut g_mask);
        let (fc, mut layers) = if recompute {
            // just-in-time rebuild from the chunk's carry-in: the
            // deterministic kernels make the recomputed caches (and
            // hence the gradients) bitwise equal to the cached path
            let fc =
                recompute_chunk_caches(cfg, p, &g_tokens, &g_pos, streams, clen, threads, ws, &sin);
            let layers = std::mem::replace(&mut ws.layers, spare.pop().unwrap_or_default());
            (fc, layers)
        } else {
            (
                heads.pop().expect("head cache per chunk"),
                filled.pop().expect("layer caches per chunk"),
            )
        };
        let (ls, dh) = head_backward(cfg, p, fc, &g_targets, &g_mask, denom, threads, ws, grads);
        loss_sum += ls;
        layers_backward(
            cfg,
            p,
            &g_tokens,
            &g_pos,
            streams,
            clen,
            threads,
            ws,
            grads,
            &mut layers,
            dh,
            Some((&sin, &mut adj)),
        );
        ws.recycle_chunk_state(sin);
        // packlint: allow(R1) -- returns a drained cache to the spare
        // pool; capacity is kept for the next step, no steady-state alloc.
        spare.push(layers);
    }
    ws.recycle_chunk_state(adj);

    // Restore the workspace spines (capacities survive to the next step).
    ws.chunk_heads = heads;
    ws.chunk_states = states;
    ws.chunk_layers = filled;
    ws.spare_layer_spines = spare;
    ws.gather_tokens = g_tokens;
    ws.gather_targets = g_targets;
    ws.gather_pos = g_pos;
    ws.gather_mask = g_mask;
    (loss_sum / denom as f64) as f32
}

/// Allocating convenience wrapper over [`loss_and_grads_chunked_into`]
/// (zero stream-start state, whole-batch denominator) — the
/// differential-test surface.
#[allow(clippy::too_many_arguments)]
pub fn loss_and_grads_chunked(
    cfg: &ModelConfig,
    p: &[Tensor],
    tokens: &[i32],
    targets: &[i32],
    pos: &[i32],
    mask: &[f32],
    rows: usize,
    len: usize,
    streams: usize,
    chunk_len: usize,
    threads: usize,
    recompute: bool,
) -> (f32, Vec<Tensor>) {
    let mut ws = ModelWorkspace::new();
    let specs = params::specs(cfg);
    let mut grads: Vec<Vec<f32>> = specs.iter().map(|s| vec![0.0f32; s.element_count()]).collect();
    let denom = ops::mask_denom(mask);
    let loss = loss_and_grads_chunked_into(
        cfg, p, tokens, targets, pos, mask, rows, len, streams, chunk_len, threads, &mut ws,
        &mut grads, denom, None, recompute,
    );
    let tensors = specs
        .iter()
        .zip(grads)
        .map(|(s, g)| Tensor::new(&s.shape, g))
        .collect();
    (loss, tensors)
}

/// Masked-cross-entropy loss and gradients for every parameter, in
/// canonical flat order (allocating convenience wrapper over
/// [`loss_and_grads_into`]).
#[allow(clippy::too_many_arguments)]
pub fn loss_and_grads(
    cfg: &ModelConfig,
    p: &[Tensor],
    tokens: &[i32],
    targets: &[i32],
    pos: &[i32],
    mask: &[f32],
    rows: usize,
    len: usize,
    threads: usize,
) -> (f32, Vec<Tensor>) {
    let mut ws = ModelWorkspace::new();
    let specs = params::specs(cfg);
    let mut grads: Vec<Vec<f32>> = specs.iter().map(|s| vec![0.0f32; s.element_count()]).collect();
    let loss = loss_and_grads_into(
        cfg, p, tokens, targets, pos, mask, rows, len, threads, &mut ws, &mut grads,
    );
    let tensors = specs
        .iter()
        .zip(grads)
        .map(|(s, g)| Tensor::new(&s.shape, g))
        .collect();
    (loss, tensors)
}

/// Canonical parameter specs (re-exported convenience).
pub fn param_specs(cfg: &ModelConfig) -> Vec<ParamSpec> {
    params::specs(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::{PackedBatch, PackedRow, Sequence};

    fn nano() -> ModelConfig {
        ModelConfig {
            name: "nano".to_string(),
            vocab_size: 29,
            d_model: 16,
            n_layers: 2,
            d_state: 4,
            d_conv: 4,
            expand: 2,
        }
    }

    fn rand_seq(id: u64, len: usize, vocab: usize) -> Sequence {
        let mut x = id.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        let tokens = (0..len)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                1 + (x % (vocab as u64 - 1)) as i32
            })
            .collect();
        Sequence { tokens, id }
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let cfg = nano();
        let p = params::init(&cfg, 1);
        let batch = PackedBatch::from_rows(
            &[PackedRow {
                sequences: vec![rand_seq(1, 9, cfg.vocab_size), rand_seq(2, 5, cfg.vocab_size)],
            }],
            16,
        );
        let mut ws = ModelWorkspace::new();
        let logits = forward_logits(
            &cfg,
            &p,
            batch.tokens.data(),
            batch.position_indices.data(),
            1,
            16,
            1,
            &mut ws,
        );
        assert_eq!(logits.shape(), &[1, 16, cfg.vocab_size]);
        assert!(logits.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn workspace_reuse_does_not_change_results() {
        // warmup-recycled (stale) arena buffers must be invisible: the
        // same batch through the same workspace twice gives identical
        // losses and gradients, and matches a fresh workspace.
        let cfg = nano();
        let p = params::init(&cfg, 3);
        let batch = PackedBatch::from_rows(
            &[PackedRow {
                sequences: vec![rand_seq(9, 11, cfg.vocab_size), rand_seq(10, 4, cfg.vocab_size)],
            }],
            16,
        );
        let specs = params::specs(&cfg);
        let mut grads_a: Vec<Vec<f32>> =
            specs.iter().map(|s| vec![0.0f32; s.element_count()]).collect();
        let mut grads_b = grads_a.clone();
        let mut ws = ModelWorkspace::new();
        let run = |ws: &mut ModelWorkspace, grads: &mut [Vec<f32>]| {
            loss_and_grads_into(
                &cfg,
                &p,
                batch.tokens.data(),
                batch.targets.data(),
                batch.position_indices.data(),
                batch.loss_mask.data(),
                1,
                16,
                1,
                ws,
                grads,
            )
        };
        let l1 = run(&mut ws, &mut grads_a);
        let l2 = run(&mut ws, &mut grads_b); // recycled buffers
        assert_eq!(l1, l2);
        assert_eq!(grads_a, grads_b);
        let (takes, hits) = ws.arena.stats();
        assert!(hits * 2 >= takes, "second step should recycle: {takes} takes, {hits} hits");
    }

    #[test]
    fn chunked_forward_matches_monolithic() {
        // The flattened-stream chunked forward must reproduce the
        // monolithic packed forward for any chunk length (fresh rows:
        // every carry is masked at the row-start pos == 0).
        let cfg = nano();
        let p = params::init(&cfg, 4);
        let batch = PackedBatch::from_rows(
            &[
                PackedRow {
                    sequences: vec![rand_seq(1, 9, cfg.vocab_size), rand_seq(2, 5, cfg.vocab_size)],
                },
                PackedRow {
                    sequences: vec![rand_seq(3, 12, cfg.vocab_size)],
                },
            ],
            16,
        );
        let mut ws = ModelWorkspace::new();
        let full = forward_logits(
            &cfg,
            &p,
            batch.tokens.data(),
            batch.position_indices.data(),
            2,
            16,
            1,
            &mut ws,
        );
        for streams in [1usize, 2] {
            for chunk_len in [1usize, 5, 16, 32] {
                let got = forward_logits_chunked(
                    &cfg,
                    &p,
                    batch.tokens.data(),
                    batch.position_indices.data(),
                    2,
                    16,
                    streams,
                    chunk_len,
                    1,
                    &mut ws,
                );
                assert_eq!(got.shape(), full.shape());
                for (a, b) in got.data().iter().zip(full.data()) {
                    assert!(
                        (a - b).abs() < 1e-5,
                        "streams {streams} chunk_len {chunk_len}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn stream_partitioned_grads_match_single_stream() {
        // Two fresh rows (every row starts at pos == 0): running them as
        // two side-by-side streams must give the same loss and gradients
        // as the one-stream row-major traversal.
        let cfg = nano();
        let p = params::init(&cfg, 8);
        let batch = PackedBatch::from_rows(
            &[
                PackedRow {
                    sequences: vec![rand_seq(1, 9, cfg.vocab_size), rand_seq(2, 5, cfg.vocab_size)],
                },
                PackedRow {
                    sequences: vec![rand_seq(3, 12, cfg.vocab_size)],
                },
            ],
            16,
        );
        let run = |streams: usize, chunk_len: usize, recompute: bool| {
            loss_and_grads_chunked(
                &cfg,
                &p,
                batch.tokens.data(),
                batch.targets.data(),
                batch.position_indices.data(),
                batch.loss_mask.data(),
                2,
                16,
                streams,
                chunk_len,
                1,
                recompute,
            )
        };
        let (l1, g1) = run(1, 7, false);
        for chunk_len in [4usize, 16] {
            let (l2, g2) = run(2, chunk_len, false);
            assert!((l1 - l2).abs() < 1e-5, "loss {l1} vs {l2}");
            for (a, b) in g1.iter().zip(&g2) {
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert!((x - y).abs() < 1e-5_f32.max(1e-4 * y.abs()), "{x} vs {y}");
                }
            }
            // recomputation re-runs the same deterministic kernels on
            // the same carry-ins: it must be *bitwise* equal, not merely
            // within tolerance
            let (l3, g3) = run(2, chunk_len, true);
            assert_eq!(l2, l3, "recompute changed the loss");
            for (a, b) in g2.iter().zip(&g3) {
                assert_eq!(a.data(), b.data(), "recompute changed a gradient");
            }
        }
    }

    #[test]
    fn junk_chunk_state_ignored_on_fresh_rows() {
        // A chunk whose stream starts fresh (pos == 0) must produce
        // identical logits under zero and junk carry-in.
        let cfg = nano();
        let p = params::init(&cfg, 6);
        let batch = PackedBatch::from_rows(
            &[PackedRow {
                sequences: vec![rand_seq(11, 10, cfg.vocab_size), rand_seq(12, 6, cfg.vocab_size)],
            }],
            16,
        );
        let mut ws = ModelWorkspace::new();
        let zero = ChunkState::zeroed(&cfg, 1, &mut ws.arena);
        let mut junk = ChunkState::zeroed(&cfg, 1, &mut ws.arena);
        for v in junk.h.iter_mut().chain(junk.tail.iter_mut()) {
            v.iter_mut().for_each(|x| *x = 37.0);
        }
        let run = |state: &ChunkState, ws: &mut ModelWorkspace| -> Vec<f32> {
            let mut out = ChunkState::uninit(&cfg, 1, &mut ws.arena);
            let fc = forward_chunk_cached(
                &cfg,
                &p,
                batch.tokens.data(),
                batch.position_indices.data(),
                1,
                16,
                1,
                ws,
                state,
                &mut out,
            );
            let logits = fc.logits.clone();
            release_forward(fc, ws);
            out.release(&mut ws.arena);
            logits
        };
        let a = run(&zero, &mut ws);
        let b = run(&junk, &mut ws);
        assert_eq!(a, b);
        zero.release(&mut ws.arena);
        junk.release(&mut ws.arena);
    }

    #[test]
    fn loss_starts_near_uniform_and_grads_are_finite() {
        let cfg = nano();
        let p = params::init(&cfg, 2);
        let batch = PackedBatch::from_rows(
            &[
                PackedRow {
                    sequences: vec![rand_seq(3, 10, cfg.vocab_size), rand_seq(4, 6, cfg.vocab_size)],
                },
                PackedRow {
                    sequences: vec![rand_seq(5, 12, cfg.vocab_size)],
                },
            ],
            16,
        );
        let (loss, grads) = loss_and_grads(
            &cfg,
            &p,
            batch.tokens.data(),
            batch.targets.data(),
            batch.position_indices.data(),
            batch.loss_mask.data(),
            2,
            16,
            1,
        );
        let uniform = (cfg.vocab_size as f32).ln();
        assert!(
            (loss - uniform).abs() < 1.0,
            "initial loss {loss} vs ln(V) {uniform}"
        );
        assert_eq!(grads.len(), params::count(&cfg));
        for (g, s) in grads.iter().zip(params::specs(&cfg)) {
            assert_eq!(g.shape(), s.shape.as_slice(), "{}", s.name);
            assert!(g.data().iter().all(|x| x.is_finite()), "{}", s.name);
        }
        // some gradient must be nonzero
        assert!(grads.iter().any(|g| g.data().iter().any(|&x| x != 0.0)));
    }

    #[test]
    fn whole_model_gradient_matches_finite_differences() {
        // Spot-check a handful of entries in every parameter tensor
        // against central differences on the real loss.
        let cfg = nano();
        let mut p = params::init(&cfg, 5);
        let batch = PackedBatch::from_rows(
            &[PackedRow {
                sequences: vec![rand_seq(7, 7, cfg.vocab_size), rand_seq(8, 5, cfg.vocab_size)],
            }],
            14,
        );
        let args = (
            batch.tokens.data().to_vec(),
            batch.targets.data().to_vec(),
            batch.position_indices.data().to_vec(),
            batch.loss_mask.data().to_vec(),
        );
        let loss_of = |p: &[Tensor]| {
            loss_and_grads(&cfg, p, &args.0, &args.1, &args.2, &args.3, 1, 14, 1).0
        };
        let (_, grads) = loss_and_grads(&cfg, &p, &args.0, &args.1, &args.2, &args.3, 1, 14, 1);
        let h = 1e-3f32;
        let mut checked = 0;
        for pi in 0..p.len() {
            let len = p[pi].len();
            for off in [0usize, len / 2, len - 1] {
                let old = p[pi].data()[off];
                p[pi].data_mut()[off] = old + h;
                let lp = loss_of(&p);
                p[pi].data_mut()[off] = old - h;
                let lm = loss_of(&p);
                p[pi].data_mut()[off] = old;
                let fd = (lp - lm) / (2.0 * h);
                let an = grads[pi].data()[off];
                assert!(
                    (fd - an).abs() < 5e-3_f32.max(0.05 * fd.abs()),
                    "param {pi} off {off}: fd {fd} analytic {an}"
                );
                checked += 1;
            }
        }
        assert!(checked > 50);
    }
}
