//! The packed Mamba LM, natively: embedding → N gated Mamba blocks
//! (packed conv1d + packed selective scan) → RMSNorm → tied-embedding
//! head, with masked cross-entropy and a full analytic backward pass.
//!
//! Faithful to `python/compile/model.py`: the same parameter shapes, the
//! same block wiring, and the same packed-operator semantics — every
//! sequence-wise op takes `position_indices` so packed neighbours never
//! exchange state (the numerics were cross-checked against the reference
//! oracles by finite differences; `tests/native_backend.rs` asserts the
//! PUI invariant end-to-end).
//!
//! Activations flow token-major `(T, ·)` through the GEMMs and
//! channel-major `(B, D, L)` through the sequence-wise kernels, with
//! explicit transposes at the boundaries (see `kernels`).

use crate::config::ModelConfig;
use crate::runtime::ParamSpec;
use crate::tensor::Tensor;

use super::kernels::{self, Dims, ScanCache};
use super::ops;
use super::params::{self, slot};

const NORM_EPS: f32 = 1e-5;

/// Per-layer activations the backward pass consumes.
struct LayerCache {
    /// block input `(T, d)`
    u: Vec<f32>,
    /// RMSNorm 1/rms per token `(T,)`
    inv: Vec<f32>,
    /// normed input `(T, d)`
    un: Vec<f32>,
    /// conv input, channel-major `(B, di, L)`
    xlin_cm: Vec<f32>,
    /// gate branch `(T, di)`
    z: Vec<f32>,
    /// conv output pre-silu, channel-major
    xc_cm: Vec<f32>,
    /// conv output post-silu (scan input), channel-major
    xs_cm: Vec<f32>,
    /// same, token-major `(T, di)`
    xs_tm: Vec<f32>,
    /// low-rank dt input `(T, r)`
    dt_low: Vec<f32>,
    /// selective B `(T, n)`
    bm: Vec<f32>,
    /// selective C `(T, n)`
    cm: Vec<f32>,
    /// dt before softplus `(T, di)`
    dt_pre: Vec<f32>,
    /// dt after softplus, channel-major
    dt_cm: Vec<f32>,
    /// scan state history + masked decay
    scan: ScanCache,
    /// scan output token-major `(T, di)`
    y_tm: Vec<f32>,
    /// gated output `y · silu(z)` `(T, di)`
    yz: Vec<f32>,
}

/// Forward activations for one packed batch.
pub struct ForwardCache {
    /// `(T, vocab)` token logits
    pub logits: Vec<f32>,
    layers: Vec<LayerCache>,
    /// pre-final-norm hidden `(T, d)`
    h_pre: Vec<f32>,
    /// post-final-norm hidden `(T, d)`
    hf: Vec<f32>,
    invf: Vec<f32>,
}

fn add_into(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (a, b) in dst.iter_mut().zip(src) {
        *a += *b;
    }
}

/// Full forward pass, caching everything the backward needs.
pub fn forward_cached(
    cfg: &ModelConfig,
    p: &[Tensor],
    tokens: &[i32],
    pos: &[i32],
    rows: usize,
    len: usize,
    threads: usize,
) -> ForwardCache {
    let (d, di, n, r, wl, v) = (
        cfg.d_model,
        cfg.d_inner(),
        cfg.d_state,
        cfg.dt_rank(),
        cfg.d_conv,
        cfg.vocab_size,
    );
    let t = rows * len;
    assert_eq!(tokens.len(), t, "token plane size");
    assert_eq!(pos.len(), t, "position plane size");
    assert_eq!(p.len(), params::count(cfg), "parameter count");
    let dims = Dims {
        b: rows,
        l: len,
        d: di,
        n,
    };

    // embedding lookup
    let emb = p[params::EMBEDDING].data();
    let mut h = vec![0.0f32; t * d];
    for (ti, &tok) in tokens.iter().enumerate() {
        let tok = tok as usize;
        assert!(tok < v, "token {tok} outside vocab {v}");
        h[ti * d..(ti + 1) * d].copy_from_slice(&emb[tok * d..(tok + 1) * d]);
    }

    let mut layers = Vec::with_capacity(cfg.n_layers);
    for li in 0..cfg.n_layers {
        let lp = |s: usize| p[params::layer_param(li, s)].data();

        let (un, inv) = ops::rms_norm_fwd(&h, d, lp(slot::NORM_W), NORM_EPS);
        let xz = ops::matmul(&un, t, d, lp(slot::IN_PROJ), 2 * di, threads);
        let mut xlin = vec![0.0f32; t * di];
        let mut z = vec![0.0f32; t * di];
        for ti in 0..t {
            xlin[ti * di..(ti + 1) * di].copy_from_slice(&xz[ti * 2 * di..ti * 2 * di + di]);
            z[ti * di..(ti + 1) * di].copy_from_slice(&xz[ti * 2 * di + di..(ti + 1) * 2 * di]);
        }

        // sequence-wise op #1: packed causal conv (state reset via pos)
        let xlin_cm = ops::to_channel_major(&xlin, rows, len, di);
        let xc_cm =
            kernels::conv1d_packed_fwd(&xlin_cm, dims, lp(slot::CONV_W), wl, lp(slot::CONV_B), pos, threads);
        let xs_cm: Vec<f32> = xc_cm.iter().map(|&x| ops::silu(x)).collect();
        let xs_tm = ops::to_token_major(&xs_cm, rows, di, len);

        // selective projections
        let stride = r + 2 * n;
        let dbc = ops::matmul(&xs_tm, t, di, lp(slot::X_PROJ), stride, threads);
        let mut dt_low = vec![0.0f32; t * r];
        let mut bm = vec![0.0f32; t * n];
        let mut cm = vec![0.0f32; t * n];
        for ti in 0..t {
            let row = &dbc[ti * stride..(ti + 1) * stride];
            dt_low[ti * r..(ti + 1) * r].copy_from_slice(&row[..r]);
            bm[ti * n..(ti + 1) * n].copy_from_slice(&row[r..r + n]);
            cm[ti * n..(ti + 1) * n].copy_from_slice(&row[r + n..]);
        }
        let mut dt_pre = ops::matmul(&dt_low, t, r, lp(slot::DT_PROJ), di, threads);
        let dt_bias = lp(slot::DT_BIAS);
        for ti in 0..t {
            let row = &mut dt_pre[ti * di..(ti + 1) * di];
            for (x, &b) in row.iter_mut().zip(dt_bias) {
                *x += b;
            }
        }
        let dt_tm: Vec<f32> = dt_pre.iter().map(|&x| ops::softplus(x)).collect();
        let dt_cm = ops::to_channel_major(&dt_tm, rows, len, di);

        // sequence-wise op #2: packed selective scan
        let a_neg: Vec<f32> = lp(slot::A_LOG).iter().map(|&x| -x.exp()).collect();
        let (y_cm, scan) =
            kernels::ssm_packed_fwd(&xs_cm, &dt_cm, &a_neg, &bm, &cm, lp(slot::D), pos, dims, threads);
        let y_tm = ops::to_token_major(&y_cm, rows, di, len);

        // gate + output projection + residual
        let mut yz = vec![0.0f32; t * di];
        for i in 0..t * di {
            yz[i] = y_tm[i] * ops::silu(z[i]);
        }
        let mut out = ops::matmul(&yz, t, di, lp(slot::OUT_PROJ), d, threads);
        add_into(&mut out, &h); // residual into the fresh projection buffer
        let u = std::mem::replace(&mut h, out);

        layers.push(LayerCache {
            u,
            inv,
            un,
            xlin_cm,
            z,
            xc_cm,
            xs_cm,
            xs_tm,
            dt_low,
            bm,
            cm,
            dt_pre,
            dt_cm,
            scan,
            y_tm,
            yz,
        });
    }

    let (hf, invf) = ops::rms_norm_fwd(&h, d, p[params::norm_f(cfg)].data(), NORM_EPS);
    let logits = ops::matmul_nt(&hf, t, d, emb, v, threads);
    ForwardCache {
        logits,
        layers,
        h_pre: h,
        hf,
        invf,
    }
}

/// Forward returning only `(rows, len, vocab)` logits — the PUI surface.
pub fn forward_logits(
    cfg: &ModelConfig,
    p: &[Tensor],
    tokens: &[i32],
    pos: &[i32],
    rows: usize,
    len: usize,
    threads: usize,
) -> Tensor {
    let fc = forward_cached(cfg, p, tokens, pos, rows, len, threads);
    Tensor::new(&[rows, len, cfg.vocab_size], fc.logits)
}

/// Masked-cross-entropy loss and gradients for every parameter, in
/// canonical flat order.
#[allow(clippy::too_many_arguments)]
pub fn loss_and_grads(
    cfg: &ModelConfig,
    p: &[Tensor],
    tokens: &[i32],
    targets: &[i32],
    pos: &[i32],
    mask: &[f32],
    rows: usize,
    len: usize,
    threads: usize,
) -> (f32, Vec<Tensor>) {
    let (d, di, n, r, wl, v) = (
        cfg.d_model,
        cfg.d_inner(),
        cfg.d_state,
        cfg.dt_rank(),
        cfg.d_conv,
        cfg.vocab_size,
    );
    let t = rows * len;
    let dims = Dims {
        b: rows,
        l: len,
        d: di,
        n,
    };
    let fc = forward_cached(cfg, p, tokens, pos, rows, len, threads);

    let specs = params::specs(cfg);
    let mut grads: Vec<Vec<f32>> = specs.iter().map(|s| vec![0.0f32; s.element_count()]).collect();

    // head: masked cross-entropy against the tied embedding
    let (loss, dlogits) = ops::cross_entropy(&fc.logits, v, targets, mask, threads);
    let emb = p[params::EMBEDDING].data();
    add_into(
        &mut grads[params::EMBEDDING],
        &ops::matmul_tn(&dlogits, t, v, &fc.hf, d, threads),
    );
    let dhf = ops::matmul(&dlogits, t, v, emb, d, threads);
    let (mut dh, dnormf) = ops::rms_norm_bwd(
        &fc.h_pre,
        d,
        p[params::norm_f(cfg)].data(),
        &fc.invf,
        &dhf,
    );
    add_into(&mut grads[params::norm_f(cfg)], &dnormf);

    for li in (0..cfg.n_layers).rev() {
        let lp = |s: usize| p[params::layer_param(li, s)].data();
        let gi = |s: usize| params::layer_param(li, s);
        let c = &fc.layers[li];
        let dout = dh; // grad of the block output, (T, d)

        // out = u + yz @ out_proj
        let dyz = ops::matmul_nt(&dout, t, d, lp(slot::OUT_PROJ), di, threads);
        add_into(
            &mut grads[gi(slot::OUT_PROJ)],
            &ops::matmul_tn(&c.yz, t, di, &dout, d, threads),
        );

        // yz = y · silu(z)
        let mut dy_tm = vec![0.0f32; t * di];
        let mut dz = vec![0.0f32; t * di];
        for i in 0..t * di {
            dy_tm[i] = dyz[i] * ops::silu(c.z[i]);
            dz[i] = dyz[i] * c.y_tm[i] * ops::dsilu(c.z[i]);
        }

        // packed selective scan backward
        let a_neg: Vec<f32> = lp(slot::A_LOG).iter().map(|&x| -x.exp()).collect();
        let dy_cm = ops::to_channel_major(&dy_tm, rows, len, di);
        let gr = kernels::ssm_packed_bwd(
            &c.xs_cm, &c.dt_cm, &a_neg, &c.bm, &c.cm, lp(slot::D), &c.scan, &dy_cm, dims, threads,
        );
        {
            // A = -exp(A_log) ⇒ ∂A/∂A_log = A
            let g = &mut grads[gi(slot::A_LOG)];
            for i in 0..di * n {
                g[i] += gr.da[i] * a_neg[i];
            }
        }
        add_into(&mut grads[gi(slot::D)], &gr.dd);

        // dt = softplus(dt_low @ dt_proj + dt_bias)
        let ddt_tm = ops::to_token_major(&gr.ddt, rows, di, len);
        let mut ddt_pre = vec![0.0f32; t * di];
        for i in 0..t * di {
            ddt_pre[i] = ddt_tm[i] * ops::sigmoid(c.dt_pre[i]);
        }
        {
            let g = &mut grads[gi(slot::DT_BIAS)];
            for ti in 0..t {
                let row = &ddt_pre[ti * di..(ti + 1) * di];
                for (a, &b) in g.iter_mut().zip(row) {
                    *a += b;
                }
            }
        }
        add_into(
            &mut grads[gi(slot::DT_PROJ)],
            &ops::matmul_tn(&c.dt_low, t, r, &ddt_pre, di, threads),
        );
        let ddt_low = ops::matmul_nt(&ddt_pre, t, di, lp(slot::DT_PROJ), r, threads);

        // dbc = xs @ x_proj, split into (dt_low | B | C)
        let stride = r + 2 * n;
        let mut ddbc = vec![0.0f32; t * stride];
        for ti in 0..t {
            ddbc[ti * stride..ti * stride + r].copy_from_slice(&ddt_low[ti * r..(ti + 1) * r]);
            ddbc[ti * stride + r..ti * stride + r + n]
                .copy_from_slice(&gr.dbm[ti * n..(ti + 1) * n]);
            ddbc[ti * stride + r + n..(ti + 1) * stride]
                .copy_from_slice(&gr.dcm[ti * n..(ti + 1) * n]);
        }
        add_into(
            &mut grads[gi(slot::X_PROJ)],
            &ops::matmul_tn(&c.xs_tm, t, di, &ddbc, stride, threads),
        );
        let mut dxs_tm = ops::matmul_nt(&ddbc, t, stride, lp(slot::X_PROJ), di, threads);
        add_into(&mut dxs_tm, &ops::to_token_major(&gr.dx, rows, di, len));

        // silu + packed conv backward
        let dxs_cm = ops::to_channel_major(&dxs_tm, rows, len, di);
        let mut dxc_cm = vec![0.0f32; rows * di * len];
        for i in 0..rows * di * len {
            dxc_cm[i] = dxs_cm[i] * ops::dsilu(c.xc_cm[i]);
        }
        let (dxlin_cm, dw, db) =
            kernels::conv1d_packed_bwd(&c.xlin_cm, dims, lp(slot::CONV_W), wl, pos, &dxc_cm, threads);
        add_into(&mut grads[gi(slot::CONV_W)], &dw);
        add_into(&mut grads[gi(slot::CONV_B)], &db);
        let dxlin_tm = ops::to_token_major(&dxlin_cm, rows, di, len);

        // xz = un @ in_proj, xz = (x | z)
        let mut dxz = vec![0.0f32; t * 2 * di];
        for ti in 0..t {
            dxz[ti * 2 * di..ti * 2 * di + di]
                .copy_from_slice(&dxlin_tm[ti * di..(ti + 1) * di]);
            dxz[ti * 2 * di + di..(ti + 1) * 2 * di].copy_from_slice(&dz[ti * di..(ti + 1) * di]);
        }
        add_into(
            &mut grads[gi(slot::IN_PROJ)],
            &ops::matmul_tn(&c.un, t, d, &dxz, 2 * di, threads),
        );
        let dun = ops::matmul_nt(&dxz, t, 2 * di, lp(slot::IN_PROJ), d, threads);

        // RMSNorm backward + residual
        let (mut dup, dnw) = ops::rms_norm_bwd(&c.u, d, lp(slot::NORM_W), &c.inv, &dun);
        add_into(&mut grads[gi(slot::NORM_W)], &dnw);
        add_into(&mut dup, &dout);
        dh = dup;
    }

    // embedding lookup gradient
    {
        let g = &mut grads[params::EMBEDDING];
        for (ti, &tok) in tokens.iter().enumerate() {
            let dst = &mut g[tok as usize * d..(tok as usize + 1) * d];
            let src = &dh[ti * d..(ti + 1) * d];
            for (a, &b) in dst.iter_mut().zip(src) {
                *a += b;
            }
        }
    }

    let tensors = specs
        .iter()
        .zip(grads)
        .map(|(s, g)| Tensor::new(&s.shape, g))
        .collect();
    (loss, tensors)
}

/// Canonical parameter specs (re-exported convenience).
pub fn param_specs(cfg: &ModelConfig) -> Vec<ParamSpec> {
    params::specs(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::{PackedBatch, PackedRow, Sequence};

    fn nano() -> ModelConfig {
        ModelConfig {
            name: "nano".to_string(),
            vocab_size: 29,
            d_model: 16,
            n_layers: 2,
            d_state: 4,
            d_conv: 4,
            expand: 2,
        }
    }

    fn rand_seq(id: u64, len: usize, vocab: usize) -> Sequence {
        let mut x = id.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        let tokens = (0..len)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                1 + (x % (vocab as u64 - 1)) as i32
            })
            .collect();
        Sequence { tokens, id }
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let cfg = nano();
        let p = params::init(&cfg, 1);
        let batch = PackedBatch::from_rows(
            &[PackedRow {
                sequences: vec![rand_seq(1, 9, cfg.vocab_size), rand_seq(2, 5, cfg.vocab_size)],
            }],
            16,
        );
        let logits = forward_logits(
            &cfg,
            &p,
            batch.tokens.data(),
            batch.position_indices.data(),
            1,
            16,
            1,
        );
        assert_eq!(logits.shape(), &[1, 16, cfg.vocab_size]);
        assert!(logits.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn loss_starts_near_uniform_and_grads_are_finite() {
        let cfg = nano();
        let p = params::init(&cfg, 2);
        let batch = PackedBatch::from_rows(
            &[
                PackedRow {
                    sequences: vec![rand_seq(3, 10, cfg.vocab_size), rand_seq(4, 6, cfg.vocab_size)],
                },
                PackedRow {
                    sequences: vec![rand_seq(5, 12, cfg.vocab_size)],
                },
            ],
            16,
        );
        let (loss, grads) = loss_and_grads(
            &cfg,
            &p,
            batch.tokens.data(),
            batch.targets.data(),
            batch.position_indices.data(),
            batch.loss_mask.data(),
            2,
            16,
            1,
        );
        let uniform = (cfg.vocab_size as f32).ln();
        assert!(
            (loss - uniform).abs() < 1.0,
            "initial loss {loss} vs ln(V) {uniform}"
        );
        assert_eq!(grads.len(), params::count(&cfg));
        for (g, s) in grads.iter().zip(params::specs(&cfg)) {
            assert_eq!(g.shape(), s.shape.as_slice(), "{}", s.name);
            assert!(g.data().iter().all(|x| x.is_finite()), "{}", s.name);
        }
        // some gradient must be nonzero
        assert!(grads.iter().any(|g| g.data().iter().any(|&x| x != 0.0)));
    }

    #[test]
    fn whole_model_gradient_matches_finite_differences() {
        // Spot-check a handful of entries in every parameter tensor
        // against central differences on the real loss.
        let cfg = nano();
        let mut p = params::init(&cfg, 5);
        let batch = PackedBatch::from_rows(
            &[PackedRow {
                sequences: vec![rand_seq(7, 7, cfg.vocab_size), rand_seq(8, 5, cfg.vocab_size)],
            }],
            14,
        );
        let args = (
            batch.tokens.data().to_vec(),
            batch.targets.data().to_vec(),
            batch.position_indices.data().to_vec(),
            batch.loss_mask.data().to_vec(),
        );
        let loss_of = |p: &[Tensor]| {
            loss_and_grads(&cfg, p, &args.0, &args.1, &args.2, &args.3, 1, 14, 1).0
        };
        let (_, grads) = loss_and_grads(&cfg, &p, &args.0, &args.1, &args.2, &args.3, 1, 14, 1);
        let h = 1e-3f32;
        let mut checked = 0;
        for pi in 0..p.len() {
            let len = p[pi].len();
            for off in [0usize, len / 2, len - 1] {
                let old = p[pi].data()[off];
                p[pi].data_mut()[off] = old + h;
                let lp = loss_of(&p);
                p[pi].data_mut()[off] = old - h;
                let lm = loss_of(&p);
                p[pi].data_mut()[off] = old;
                let fd = (lp - lm) / (2.0 * h);
                let an = grads[pi].data()[off];
                assert!(
                    (fd - an).abs() < 5e-3_f32.max(0.05 * fd.abs()),
                    "param {pi} off {off}: fd {fd} analytic {an}"
                );
                checked += 1;
            }
        }
        assert!(checked > 50);
    }
}
