//! The paper's modified sequence-wise operators, natively on the CPU:
//! **packed causal depthwise conv1d** (§3.3) and the **packed selective
//! scan** (§3.1/§3.4–3.5), forward *and* backward.
//!
//! Both take the `position_indices` plane produced by `pack()` and reset
//! state at every `pos == 0` slot, so packed neighbours never exchange
//! information:
//!
//! * conv: tap `j` (reaching back `shift = W-1-j` steps) contributes only
//!   where `pos[t] >= shift` — the own-sequence guard of Algorithm 1;
//! * scan: the multiplicative term `Ā = exp(Δ·A)` is zeroed at `pos == 0`,
//!   killing every prefix product that crosses a boundary (Algorithm 2's
//!   segmented formulation).
//!
//! Layout: activations are **channel-major** `(B, D, L)` here so each
//! `(row, channel)` lane is a contiguous stretch one pool task can own
//! (`util::threadpool::parallel_chunks_mut`); the model layer transposes
//! at the GEMM boundaries.  Every parallel loop below — fwd, bwd, and
//! the chunked carry variants — dispatches onto the **persistent parked
//! `WorkerPool`** through that primitive, so the multi-threaded steady
//! state spawns no threads and allocates nothing (`tests/zero_alloc.rs`
//! audits it at threads = 4).  Scan state history `(B, D, L, N)` and the
//! masked decay `Ā` are cached by the forward for the backward pass.
//!
//! Every kernel has an `_into` form writing caller-provided buffers (the
//! `StepArena` path — no heap allocation, no per-lane scratch: the
//! recurrences read their own already-written output rows instead of
//! keeping a scratch state vector), plus allocating wrappers for tests
//! and benches.  Invariant slices (the per-lane `bm`/`cm`/`pos` bases,
//! the per-channel `a` row) are hoisted out of the time loops.
//! All reductions have a fixed order, so results are independent of
//! thread count.

use crate::util::threadpool::parallel_chunks_mut;
use crate::util::trace::{self, Op};

/// Geometry of one packed operator call.
#[derive(Clone, Copy, Debug)]
pub struct Dims {
    /// packed rows
    pub b: usize,
    /// slots per row (pack_len)
    pub l: usize,
    /// channels (d_inner)
    pub d: usize,
    /// SSM state dimension
    pub n: usize,
}

impl Dims {
    fn lanes(&self) -> usize {
        self.b * self.d
    }
}

fn lane_threads(dims: Dims, work_per_slot: usize, threads: usize) -> usize {
    if dims.lanes() * dims.l * work_per_slot < 1 << 20 {
        1
    } else {
        threads.max(1)
    }
}

/// Packed causal depthwise conv1d forward, into `y`.
///
/// `x`: `(B, D, L)` channel-major; `w`: `(W, D)`; `bias`: `(D)`;
/// `pos`: `(B, L)`.
#[allow(clippy::too_many_arguments)]
pub fn conv1d_packed_fwd_into(
    x: &[f32],
    dims: Dims,
    w: &[f32],
    wlen: usize,
    bias: &[f32],
    pos: &[i32],
    threads: usize,
    y: &mut [f32],
) {
    let _sp = trace::span(Op::Conv1dFwd);
    let Dims { b, l, d, .. } = dims;
    assert_eq!(x.len(), b * d * l);
    assert_eq!(w.len(), wlen * d);
    assert_eq!(bias.len(), d);
    assert_eq!(pos.len(), b * l);
    assert_eq!(y.len(), b * d * l);
    let threads = lane_threads(dims, wlen, threads);
    parallel_chunks_mut(y, l, threads, |lane, out| {
        let (bi, c) = (lane / d, lane % d);
        let xrow = &x[lane * l..(lane + 1) * l];
        let prow = &pos[bi * l..(bi + 1) * l];
        let bc = bias[c];
        for t in 0..l {
            let mut acc = bc;
            for j in 0..wlen {
                let shift = wlen - 1 - j;
                if t >= shift && prow[t] >= shift as i32 {
                    acc += w[j * d + c] * xrow[t - shift];
                }
            }
            out[t] = acc;
        }
    });
}

/// Packed causal depthwise conv1d forward **with cross-chunk carry**
/// (paper §5), into `y` and `tail_out`.
///
/// `tail` holds the previous chunk's final `W-1` conv *inputs* per lane,
/// `(B, D, W-1)` lane-major: `tail[lane][k]` is the input at stream
/// offset `k - (W-1)` relative to this chunk's first slot.  A tap that
/// reaches past the chunk start reads the tail; the same `pos >= shift`
/// guard that isolates packed neighbours admits the tail exactly when
/// this chunk *continues* a sequence deep enough — a fresh start
/// (`pos == 0`) masks the carry out entirely, so chunk-boundary carry
/// and sequence-boundary isolation compose.  `tail_out` receives this
/// chunk's own final `W-1` inputs (falling back to carried slots when
/// `L < W-1`), ready to be the next chunk's `tail`.
#[allow(clippy::too_many_arguments)]
pub fn conv1d_packed_fwd_carry_into(
    x: &[f32],
    dims: Dims,
    w: &[f32],
    wlen: usize,
    bias: &[f32],
    pos: &[i32],
    tail: &[f32],
    threads: usize,
    y: &mut [f32],
    tail_out: &mut [f32],
) {
    let _sp = trace::span(Op::Conv1dFwd);
    let Dims { b, l, d, .. } = dims;
    let tw = wlen - 1;
    assert_eq!(x.len(), b * d * l);
    assert_eq!(w.len(), wlen * d);
    assert_eq!(bias.len(), d);
    assert_eq!(pos.len(), b * l);
    assert_eq!(tail.len(), b * d * tw);
    assert_eq!(y.len(), b * d * l);
    assert_eq!(tail_out.len(), b * d * tw);
    let threads = lane_threads(dims, wlen, threads);
    parallel_chunks_mut(y, l, threads, |lane, out| {
        let (bi, c) = (lane / d, lane % d);
        let xrow = &x[lane * l..(lane + 1) * l];
        let trow = &tail[lane * tw..(lane + 1) * tw];
        let prow = &pos[bi * l..(bi + 1) * l];
        let bc = bias[c];
        for t in 0..l {
            let mut acc = bc;
            for j in 0..wlen {
                let shift = wlen - 1 - j;
                if prow[t] >= shift as i32 {
                    let xv = if t >= shift {
                        xrow[t - shift]
                    } else {
                        // stream offset t - shift < 0 lands in the tail
                        trow[tw + t - shift]
                    };
                    acc += w[j * d + c] * xv;
                }
            }
            out[t] = acc;
        }
    });
    // Carry-out: the stream's last W-1 inputs per lane (cheap; serial).
    for lane in 0..b * d {
        let xrow = &x[lane * l..(lane + 1) * l];
        let trow = &tail[lane * tw..(lane + 1) * tw];
        let orow = &mut tail_out[lane * tw..(lane + 1) * tw];
        for (m, o) in orow.iter_mut().enumerate() {
            // outgoing slot m sits at stream offset l - (W-1) + m
            *o = if l + m >= tw { xrow[l + m - tw] } else { trow[l + m] };
        }
    }
}

/// Packed causal depthwise conv1d forward; returns `y` channel-major.
pub fn conv1d_packed_fwd(
    x: &[f32],
    dims: Dims,
    w: &[f32],
    wlen: usize,
    bias: &[f32],
    pos: &[i32],
    threads: usize,
) -> Vec<f32> {
    let mut y = vec![0.0f32; dims.b * dims.d * dims.l];
    conv1d_packed_fwd_into(x, dims, w, wlen, bias, pos, threads, &mut y);
    y
}

/// Packed conv1d backward, into caller buffers: writes `dx`
/// (channel-major) and **accumulates** into `dw_acc` (`(W, D)`) and
/// `db_acc` (`(D)`).  `colbuf` is `(D, W+1)` scratch for the per-channel
/// reduction (one parallel task per channel, fixed `(b, t)` order).
#[allow(clippy::too_many_arguments)]
pub fn conv1d_packed_bwd_into(
    x: &[f32],
    dims: Dims,
    w: &[f32],
    wlen: usize,
    pos: &[i32],
    dy: &[f32],
    threads: usize,
    dx: &mut [f32],
    dw_acc: &mut [f32],
    db_acc: &mut [f32],
    colbuf: &mut [f32],
) {
    let _sp = trace::span(Op::Conv1dBwd);
    let Dims { b, l, d, .. } = dims;
    assert_eq!(x.len(), b * d * l);
    assert_eq!(dy.len(), b * d * l);
    assert_eq!(dx.len(), b * d * l);
    assert_eq!(dw_acc.len(), wlen * d);
    assert_eq!(db_acc.len(), d);
    assert_eq!(colbuf.len(), d * (wlen + 1));
    let threads = lane_threads(dims, wlen, threads);

    // dx: token t' receives tap contributions from outputs t'+shift that
    // looked back at it (same guard as the forward).
    parallel_chunks_mut(dx, l, threads, |lane, out| {
        let (bi, c) = (lane / d, lane % d);
        let gyrow = &dy[lane * l..(lane + 1) * l];
        let prow = &pos[bi * l..(bi + 1) * l];
        for tp in 0..l {
            let mut acc = 0.0f32;
            for shift in 0..wlen {
                let t = tp + shift;
                if t < l && prow[t] >= shift as i32 {
                    acc += w[(wlen - 1 - shift) * d + c] * gyrow[t];
                }
            }
            out[tp] = acc;
        }
    });

    // dw / dbias: one task per channel into its (W+1)-wide colbuf slot.
    parallel_chunks_mut(colbuf, wlen + 1, threads, |c, slot| {
        slot.iter_mut().for_each(|v| *v = 0.0);
        let (dwc, dbc) = slot.split_at_mut(wlen);
        for bi in 0..b {
            let lane = bi * d + c;
            let xrow = &x[lane * l..(lane + 1) * l];
            let gyrow = &dy[lane * l..(lane + 1) * l];
            let prow = &pos[bi * l..(bi + 1) * l];
            for t in 0..l {
                let g = gyrow[t];
                dbc[0] += g;
                if g != 0.0 {
                    for j in 0..wlen {
                        let shift = wlen - 1 - j;
                        if t >= shift && prow[t] >= shift as i32 {
                            dwc[j] += g * xrow[t - shift];
                        }
                    }
                }
            }
        }
    });
    for c in 0..d {
        let slot = &colbuf[c * (wlen + 1)..(c + 1) * (wlen + 1)];
        for j in 0..wlen {
            dw_acc[j * d + c] += slot[j];
        }
        db_acc[c] += slot[wlen];
    }
}

/// Packed conv1d backward; returns `(dx, dw, dbias)` with `dx`
/// channel-major and `dw` in `(W, D)` layout.
pub fn conv1d_packed_bwd(
    x: &[f32],
    dims: Dims,
    w: &[f32],
    wlen: usize,
    pos: &[i32],
    dy: &[f32],
    threads: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; dims.b * dims.d * dims.l];
    let mut dw = vec![0.0f32; wlen * dims.d];
    let mut db = vec![0.0f32; dims.d];
    let mut colbuf = vec![0.0f32; dims.d * (wlen + 1)];
    conv1d_packed_bwd_into(
        x, dims, w, wlen, pos, dy, threads, &mut dx, &mut dw, &mut db, &mut colbuf,
    );
    (dx, dw, db)
}

/// Packed conv1d backward **with cross-chunk carry**, into caller
/// buffers.
///
/// Extends [`conv1d_packed_bwd_into`] with the two carry adjoints: taps
/// that read the incoming `tail` route their input-gradient into
/// `dtail_out` (this chunk's gradient w.r.t. the *previous* chunk's
/// final inputs, to be consumed by that chunk's backward), and
/// `dtail_next` — the next chunk's `dtail_out` — folds into `dx` on the
/// slots that formed this chunk's outgoing tail (passing through to
/// `dtail_out` when `L < W-1`).  `dw_acc`/`db_acc` accumulate; `dx` and
/// `dtail_out` are fully overwritten.
#[allow(clippy::too_many_arguments)]
pub fn conv1d_packed_bwd_carry_into(
    x: &[f32],
    dims: Dims,
    w: &[f32],
    wlen: usize,
    pos: &[i32],
    tail: &[f32],
    dy: &[f32],
    dtail_next: &[f32],
    threads: usize,
    dx: &mut [f32],
    dw_acc: &mut [f32],
    db_acc: &mut [f32],
    dtail_out: &mut [f32],
    colbuf: &mut [f32],
) {
    let _sp = trace::span(Op::Conv1dBwd);
    let Dims { b, l, d, .. } = dims;
    let tw = wlen - 1;
    assert_eq!(x.len(), b * d * l);
    assert_eq!(dy.len(), b * d * l);
    assert_eq!(dx.len(), b * d * l);
    assert_eq!(tail.len(), b * d * tw);
    assert_eq!(dtail_next.len(), b * d * tw);
    assert_eq!(dtail_out.len(), b * d * tw);
    assert_eq!(dw_acc.len(), wlen * d);
    assert_eq!(db_acc.len(), d);
    assert_eq!(colbuf.len(), d * (wlen + 1));
    let threads = lane_threads(dims, wlen, threads);

    // dx: in-chunk tap gather, plus the outgoing-tail adjoint on the
    // final W-1 slots (x[t] is also carry-out slot t - (l - (W-1))).
    parallel_chunks_mut(dx, l, threads, |lane, out| {
        let (bi, c) = (lane / d, lane % d);
        let gyrow = &dy[lane * l..(lane + 1) * l];
        let dtrow = &dtail_next[lane * tw..(lane + 1) * tw];
        let prow = &pos[bi * l..(bi + 1) * l];
        for tp in 0..l {
            let mut acc = 0.0f32;
            for shift in 0..wlen {
                let t = tp + shift;
                if t < l && prow[t] >= shift as i32 {
                    acc += w[(wlen - 1 - shift) * d + c] * gyrow[t];
                }
            }
            if tp + tw >= l {
                acc += dtrow[tp + tw - l];
            }
            out[tp] = acc;
        }
    });

    // dtail_out: gradient w.r.t. the incoming tail — outputs t read tail
    // slot k via shift = t + (W-1) - k — plus the pass-through of
    // surviving slots when the chunk is shorter than the window.
    for lane in 0..b * d {
        let (bi, c) = (lane / d, lane % d);
        let gyrow = &dy[lane * l..(lane + 1) * l];
        let dtrow = &dtail_next[lane * tw..(lane + 1) * tw];
        let prow = &pos[bi * l..(bi + 1) * l];
        let orow = &mut dtail_out[lane * tw..(lane + 1) * tw];
        for (k, o) in orow.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for t in 0..l.min(k + 1) {
                let shift = t + tw - k;
                if prow[t] >= shift as i32 {
                    acc += w[(wlen - 1 - shift) * d + c] * gyrow[t];
                }
            }
            if k >= l {
                acc += dtrow[k - l];
            }
            *o = acc;
        }
    }

    // dw / dbias: as the plain backward, with tail-sourced taps included.
    parallel_chunks_mut(colbuf, wlen + 1, threads, |c, slot| {
        slot.iter_mut().for_each(|v| *v = 0.0);
        let (dwc, dbc) = slot.split_at_mut(wlen);
        for bi in 0..b {
            let lane = bi * d + c;
            let xrow = &x[lane * l..(lane + 1) * l];
            let trow = &tail[lane * tw..(lane + 1) * tw];
            let gyrow = &dy[lane * l..(lane + 1) * l];
            let prow = &pos[bi * l..(bi + 1) * l];
            for t in 0..l {
                let g = gyrow[t];
                dbc[0] += g;
                if g != 0.0 {
                    for j in 0..wlen {
                        let shift = wlen - 1 - j;
                        if prow[t] >= shift as i32 {
                            let xv = if t >= shift {
                                xrow[t - shift]
                            } else {
                                trow[tw + t - shift]
                            };
                            dwc[j] += g * xv;
                        }
                    }
                }
            }
        }
    });
    for c in 0..d {
        let slot = &colbuf[c * (wlen + 1)..(c + 1) * (wlen + 1)];
        for j in 0..wlen {
            dw_acc[j * d + c] += slot[j];
        }
        db_acc[c] += slot[wlen];
    }
}

/// State history the scan forward caches for its backward.
pub struct ScanCache {
    /// `h_t` per slot: `(B, D, L, N)`
    pub hist: Vec<f32>,
    /// masked decay `Ā_t = exp(Δ_t A) · [pos_t != 0]`: `(B, D, L, N)`
    pub am: Vec<f32>,
}

/// Packed selective scan forward (full S6 semantics), into caller
/// buffers: `y` `(B, D, L)`, plus the backward caches `hist`/`am`
/// (`(B, D, L, N)` each).
///
/// `x`, `dt`: `(B, D, L)` channel-major; `a`: `(D, N)` (negative
/// continuous-time matrix); `bm`, `cm`: `(B, L, N)` token-major
/// (selective, shared across channels); `dvec`: `(D)` skip; `pos`:
/// `(B, L)`.
#[allow(clippy::too_many_arguments)]
pub fn ssm_packed_fwd_into(
    x: &[f32],
    dt: &[f32],
    a: &[f32],
    bm: &[f32],
    cm: &[f32],
    dvec: &[f32],
    pos: &[i32],
    dims: Dims,
    threads: usize,
    y: &mut [f32],
    hist: &mut [f32],
    am: &mut [f32],
) {
    let _sp = trace::span(Op::ScanFwd);
    let Dims { b, l, d, n } = dims;
    assert_eq!(x.len(), b * d * l);
    assert_eq!(dt.len(), b * d * l);
    assert_eq!(a.len(), d * n);
    assert_eq!(bm.len(), b * l * n);
    assert_eq!(cm.len(), b * l * n);
    assert_eq!(dvec.len(), d);
    assert_eq!(pos.len(), b * l);
    assert_eq!(y.len(), b * d * l);
    assert_eq!(hist.len(), b * d * l * n);
    assert_eq!(am.len(), b * d * l * n);
    let threads = lane_threads(dims, 4 * n, threads);

    // Pass 1a: the masked decay Ā (needs only dt/a/pos).
    parallel_chunks_mut(am, l * n, threads, |lane, amc| {
        let (bi, c) = (lane / d, lane % d);
        let dtrow = &dt[lane * l..(lane + 1) * l];
        let arow = &a[c * n..(c + 1) * n];
        let prow = &pos[bi * l..(bi + 1) * l];
        for t in 0..l {
            let slot = &mut amc[t * n..(t + 1) * n];
            if prow[t] == 0 {
                slot.iter_mut().for_each(|v| *v = 0.0);
            } else {
                let dtv = dtrow[t];
                for (sv, &av) in slot.iter_mut().zip(arow) {
                    *sv = (dtv * av).exp();
                }
            }
        }
    });

    // Pass 1b: recurrence h_t = Ā_t h_{t-1} + Δ_t x_t B_t.  Each lane owns
    // its (L, N) slab; the previous state is read back from the slab
    // itself, so no per-lane scratch vector is needed.
    let am_ref = &*am;
    parallel_chunks_mut(hist, l * n, threads, |lane, hc| {
        let bi = lane / d;
        let dtrow = &dt[lane * l..(lane + 1) * l];
        let xrow = &x[lane * l..(lane + 1) * l];
        let amc = &am_ref[lane * l * n..(lane + 1) * l * n];
        let bmb = &bm[bi * l * n..(bi + 1) * l * n];
        for t in 0..l {
            let dx_t = dtrow[t] * xrow[t];
            let brow = &bmb[t * n..(t + 1) * n];
            let (done, rest) = hc.split_at_mut(t * n);
            let hrow = &mut rest[..n];
            if t == 0 {
                for nn in 0..n {
                    hrow[nn] = dx_t * brow[nn];
                }
            } else {
                let arow = &amc[t * n..(t + 1) * n];
                let hprev = &done[(t - 1) * n..];
                for nn in 0..n {
                    hrow[nn] = arow[nn] * hprev[nn] + dx_t * brow[nn];
                }
            }
        }
    });

    // Pass 2: y_t = C_t · h_t + D x_t.
    let hist_ref = &*hist;
    parallel_chunks_mut(y, l, threads, |lane, out| {
        let (bi, c) = (lane / d, lane % d);
        let xrow = &x[lane * l..(lane + 1) * l];
        let hc = &hist_ref[lane * l * n..(lane + 1) * l * n];
        let cmb = &cm[bi * l * n..(bi + 1) * l * n];
        let dv = dvec[c];
        for t in 0..l {
            let crow = &cmb[t * n..(t + 1) * n];
            let hrow = &hc[t * n..(t + 1) * n];
            let mut acc = dv * xrow[t];
            for nn in 0..n {
                acc += crow[nn] * hrow[nn];
            }
            out[t] = acc;
        }
    });
}

/// Packed selective scan forward; returns `(y, cache)`.
#[allow(clippy::too_many_arguments)]
pub fn ssm_packed_fwd(
    x: &[f32],
    dt: &[f32],
    a: &[f32],
    bm: &[f32],
    cm: &[f32],
    dvec: &[f32],
    pos: &[i32],
    dims: Dims,
    threads: usize,
) -> (Vec<f32>, ScanCache) {
    let Dims { b, l, d, n } = dims;
    let mut y = vec![0.0f32; b * d * l];
    let mut hist = vec![0.0f32; b * d * l * n];
    let mut am = vec![0.0f32; b * d * l * n];
    ssm_packed_fwd_into(
        x, dt, a, bm, cm, dvec, pos, dims, threads, &mut y, &mut hist, &mut am,
    );
    (y, ScanCache { hist, am })
}

/// Packed selective scan forward **with cross-chunk carry** (paper §5),
/// into caller buffers.
///
/// `h0` is the SSM state at the previous chunk's final slot, `(B, D, N)`
/// lane-major; the recurrence's first step reads it through the masked
/// decay `Ā_0` — at a fresh sequence start (`pos == 0`) `Ā` is zero, so
/// the carry is discarded by the same mask that isolates packed
/// neighbours.  `h_out` receives this chunk's final-slot state, ready to
/// be the next chunk's `h0`.
#[allow(clippy::too_many_arguments)]
pub fn ssm_packed_fwd_carry_into(
    x: &[f32],
    dt: &[f32],
    a: &[f32],
    bm: &[f32],
    cm: &[f32],
    dvec: &[f32],
    pos: &[i32],
    dims: Dims,
    h0: &[f32],
    threads: usize,
    y: &mut [f32],
    hist: &mut [f32],
    am: &mut [f32],
    h_out: &mut [f32],
) {
    let _sp = trace::span(Op::ScanFwd);
    let Dims { b, l, d, n } = dims;
    assert_eq!(x.len(), b * d * l);
    assert_eq!(dt.len(), b * d * l);
    assert_eq!(a.len(), d * n);
    assert_eq!(bm.len(), b * l * n);
    assert_eq!(cm.len(), b * l * n);
    assert_eq!(dvec.len(), d);
    assert_eq!(pos.len(), b * l);
    assert_eq!(h0.len(), b * d * n);
    assert_eq!(y.len(), b * d * l);
    assert_eq!(hist.len(), b * d * l * n);
    assert_eq!(am.len(), b * d * l * n);
    assert_eq!(h_out.len(), b * d * n);
    let threads = lane_threads(dims, 4 * n, threads);

    // Pass 1a: the masked decay Ā — identical to the carry-free form.
    parallel_chunks_mut(am, l * n, threads, |lane, amc| {
        let (bi, c) = (lane / d, lane % d);
        let dtrow = &dt[lane * l..(lane + 1) * l];
        let arow = &a[c * n..(c + 1) * n];
        let prow = &pos[bi * l..(bi + 1) * l];
        for t in 0..l {
            let slot = &mut amc[t * n..(t + 1) * n];
            if prow[t] == 0 {
                slot.iter_mut().for_each(|v| *v = 0.0);
            } else {
                let dtv = dtrow[t];
                for (sv, &av) in slot.iter_mut().zip(arow) {
                    *sv = (dtv * av).exp();
                }
            }
        }
    });

    // Pass 1b: recurrence with h_{-1} = h0 (Ā_0 already carries the
    // fresh-start mask, so a pos==0 chunk ignores the carry).
    let am_ref = &*am;
    parallel_chunks_mut(hist, l * n, threads, |lane, hc| {
        let bi = lane / d;
        let dtrow = &dt[lane * l..(lane + 1) * l];
        let xrow = &x[lane * l..(lane + 1) * l];
        let amc = &am_ref[lane * l * n..(lane + 1) * l * n];
        let bmb = &bm[bi * l * n..(bi + 1) * l * n];
        let h0c = &h0[lane * n..(lane + 1) * n];
        for t in 0..l {
            let dx_t = dtrow[t] * xrow[t];
            let brow = &bmb[t * n..(t + 1) * n];
            let arow = &amc[t * n..(t + 1) * n];
            let (done, rest) = hc.split_at_mut(t * n);
            let hrow = &mut rest[..n];
            let hprev: &[f32] = if t == 0 { h0c } else { &done[(t - 1) * n..] };
            for nn in 0..n {
                hrow[nn] = arow[nn] * hprev[nn] + dx_t * brow[nn];
            }
        }
    });

    // Pass 2: y_t = C_t · h_t + D x_t — identical to the carry-free form.
    let hist_ref = &*hist;
    parallel_chunks_mut(y, l, threads, |lane, out| {
        let (bi, c) = (lane / d, lane % d);
        let xrow = &x[lane * l..(lane + 1) * l];
        let hc = &hist_ref[lane * l * n..(lane + 1) * l * n];
        let cmb = &cm[bi * l * n..(bi + 1) * l * n];
        let dv = dvec[c];
        for t in 0..l {
            let crow = &cmb[t * n..(t + 1) * n];
            let hrow = &hc[t * n..(t + 1) * n];
            let mut acc = dv * xrow[t];
            for nn in 0..n {
                acc += crow[nn] * hrow[nn];
            }
            out[t] = acc;
        }
    });

    // Carry-out: the final slot's state per lane.
    for lane in 0..b * d {
        let src = &hist_ref[(lane * l + (l - 1)) * n..(lane * l + l) * n];
        h_out[lane * n..(lane + 1) * n].copy_from_slice(src);
    }
}

/// Forward-only packed selective scan: same semantics as
/// [`ssm_packed_fwd`] but fused into one pass with O(N) scratch per
/// lane — no state history, no decay cache.  Use it when no backward
/// will follow (inference, PUI checks, operator benches); at paper-ish
/// dims the cache the training forward materializes is hundreds of MB.
#[allow(clippy::too_many_arguments)]
pub fn ssm_packed_fwd_nocache(
    x: &[f32],
    dt: &[f32],
    a: &[f32],
    bm: &[f32],
    cm: &[f32],
    dvec: &[f32],
    pos: &[i32],
    dims: Dims,
    threads: usize,
) -> Vec<f32> {
    let _sp = trace::span(Op::ScanFwd);
    let Dims { b, l, d, n } = dims;
    assert_eq!(x.len(), b * d * l);
    assert_eq!(dt.len(), b * d * l);
    assert_eq!(a.len(), d * n);
    assert_eq!(bm.len(), b * l * n);
    assert_eq!(cm.len(), b * l * n);
    assert_eq!(dvec.len(), d);
    assert_eq!(pos.len(), b * l);
    let threads = lane_threads(dims, 4 * n, threads);
    let mut y = vec![0.0f32; b * d * l];
    parallel_chunks_mut(&mut y, l, threads, |lane, out| {
        let (bi, c) = (lane / d, lane % d);
        let xrow = &x[lane * l..(lane + 1) * l];
        let dtrow = &dt[lane * l..(lane + 1) * l];
        let arow = &a[c * n..(c + 1) * n];
        let prow = &pos[bi * l..(bi + 1) * l];
        let bmb = &bm[bi * l * n..(bi + 1) * l * n];
        let cmb = &cm[bi * l * n..(bi + 1) * l * n];
        let dv = dvec[c];
        let mut h = vec![0.0f32; n];
        for t in 0..l {
            let dx_t = dtrow[t] * xrow[t];
            let brow = &bmb[t * n..(t + 1) * n];
            let crow = &cmb[t * n..(t + 1) * n];
            let mut acc = dv * xrow[t];
            if prow[t] == 0 {
                for nn in 0..n {
                    h[nn] = dx_t * brow[nn];
                    acc += crow[nn] * h[nn];
                }
            } else {
                for nn in 0..n {
                    h[nn] = (dtrow[t] * arow[nn]).exp() * h[nn] + dx_t * brow[nn];
                    acc += crow[nn] * h[nn];
                }
            }
            out[t] = acc;
        }
    });
    y
}

/// Gradients of the packed selective scan (owned form).
pub struct SsmGrads {
    /// `(B, D, L)` channel-major
    pub dx: Vec<f32>,
    /// `(B, D, L)` channel-major
    pub ddt: Vec<f32>,
    /// `(D, N)`
    pub da: Vec<f32>,
    /// `(B, L, N)`
    pub dbm: Vec<f32>,
    /// `(B, L, N)`
    pub dcm: Vec<f32>,
    /// `(D)`
    pub dd: Vec<f32>,
}

/// Borrowed output buffers for [`ssm_packed_bwd_into`]; every slice is
/// fully overwritten.
pub struct SsmGradsMut<'a> {
    pub dx: &'a mut [f32],
    pub ddt: &'a mut [f32],
    pub da: &'a mut [f32],
    pub dbm: &'a mut [f32],
    pub dcm: &'a mut [f32],
    pub dd: &'a mut [f32],
}

/// Packed selective scan backward, into caller buffers.
///
/// The adjoint of the masked first-order recurrence: with
/// `g_t = ∂L/∂h_t`, the reverse scan is `g_t = C_t·dy_t + Ā_{t+1} g_{t+1}`
/// — the same boundary mask isolates sequences in both directions, so no
/// gradient crosses a packed boundary either.
///
/// `g` is `(B, D, L, N)` scratch for the reverse-scan state; `colbuf` is
/// `(D, N+1)` scratch for the per-channel `dA`/`dD` reduction.
#[allow(clippy::too_many_arguments)]
pub fn ssm_packed_bwd_into(
    x: &[f32],
    dt: &[f32],
    a: &[f32],
    bm: &[f32],
    cm: &[f32],
    dvec: &[f32],
    hist: &[f32],
    am: &[f32],
    dy: &[f32],
    dims: Dims,
    threads: usize,
    out: SsmGradsMut<'_>,
    g: &mut [f32],
    colbuf: &mut [f32],
) {
    let _sp = trace::span(Op::ScanBwd);
    let Dims { b, l, d, n } = dims;
    assert_eq!(dy.len(), b * d * l);
    assert_eq!(hist.len(), b * d * l * n);
    assert_eq!(am.len(), b * d * l * n);
    assert_eq!(g.len(), b * d * l * n);
    assert_eq!(colbuf.len(), d * (n + 1));
    assert_eq!(out.dx.len(), b * d * l);
    assert_eq!(out.ddt.len(), b * d * l);
    assert_eq!(out.da.len(), d * n);
    assert_eq!(out.dbm.len(), b * l * n);
    assert_eq!(out.dcm.len(), b * l * n);
    assert_eq!(out.dd.len(), d);
    let threads = lane_threads(dims, 8 * n, threads);

    // Pass 1: reverse scan for g = dL/dh, one lane per (row, channel).
    // The incoming state Ā_{t+1}·g_{t+1} is recomputed from the already-
    // written g row — no per-lane scratch vector.
    parallel_chunks_mut(g, l * n, threads, |lane, gc| {
        let bi = lane / d;
        let gyrow = &dy[lane * l..(lane + 1) * l];
        let amc = &am[lane * l * n..(lane + 1) * l * n];
        let cmb = &cm[bi * l * n..(bi + 1) * l * n];
        for t in (0..l).rev() {
            let gy = gyrow[t];
            let crow = &cmb[t * n..(t + 1) * n];
            let (cur, done) = gc.split_at_mut((t + 1) * n);
            let grow = &mut cur[t * n..];
            if t + 1 == l {
                for nn in 0..n {
                    grow[nn] = gy * crow[nn];
                }
            } else {
                let gnext = &done[..n];
                let anext = &amc[(t + 1) * n..(t + 2) * n];
                for nn in 0..n {
                    grow[nn] = gy * crow[nn] + anext[nn] * gnext[nn];
                }
            }
        }
    });
    let g_ref = &*g;

    // Pass 2: dx_t = D·dy_t + Σ_n g_t Δ_t B_t.
    parallel_chunks_mut(out.dx, l, threads, |lane, out| {
        let (bi, c) = (lane / d, lane % d);
        let gyrow = &dy[lane * l..(lane + 1) * l];
        let dtrow = &dt[lane * l..(lane + 1) * l];
        let gc = &g_ref[lane * l * n..(lane + 1) * l * n];
        let bmb = &bm[bi * l * n..(bi + 1) * l * n];
        let dv = dvec[c];
        for t in 0..l {
            let brow = &bmb[t * n..(t + 1) * n];
            let grow = &gc[t * n..(t + 1) * n];
            let mut dot = 0.0f32;
            for nn in 0..n {
                dot += grow[nn] * brow[nn];
            }
            out[t] = dv * gyrow[t] + dot * dtrow[t];
        }
    });

    // Pass 3: ddt_t = Σ_n (g_t h_{t-1}) A Ā_t + Σ_n g_t x_t B_t.
    // (g·h_{t-1}·mask·A·exp(ΔA) folds to g·h_{t-1}·A·Ā since Ā caches the
    // mask; at pos==0 the Ā factor is zero, so no decay gradient leaks
    // across the boundary.)
    parallel_chunks_mut(out.ddt, l, threads, |lane, out| {
        let (bi, c) = (lane / d, lane % d);
        let xrow = &x[lane * l..(lane + 1) * l];
        let arow = &a[c * n..(c + 1) * n];
        let gc = &g_ref[lane * l * n..(lane + 1) * l * n];
        let hc = &hist[lane * l * n..(lane + 1) * l * n];
        let amc = &am[lane * l * n..(lane + 1) * l * n];
        let bmb = &bm[bi * l * n..(bi + 1) * l * n];
        for t in 0..l {
            let brow = &bmb[t * n..(t + 1) * n];
            let grow = &gc[t * n..(t + 1) * n];
            let arow_m = &amc[t * n..(t + 1) * n];
            let mut acc = 0.0f32;
            if t > 0 {
                let hprev = &hc[(t - 1) * n..t * n];
                for nn in 0..n {
                    acc += grow[nn] * hprev[nn] * arow[nn] * arow_m[nn];
                }
            }
            let mut dot = 0.0f32;
            for nn in 0..n {
                dot += grow[nn] * brow[nn];
            }
            out[t] = acc + dot * xrow[t];
        }
    });

    // Pass 4: per-channel reductions dA[c, n] and dD[c] over (b, t), one
    // task per channel into its (N+1)-wide colbuf slot.
    parallel_chunks_mut(colbuf, n + 1, threads, |c, slot| {
        slot.iter_mut().for_each(|v| *v = 0.0);
        let (dac, ddc) = slot.split_at_mut(n);
        for bi in 0..b {
            let lane = bi * d + c;
            let xrow = &x[lane * l..(lane + 1) * l];
            let dtrow = &dt[lane * l..(lane + 1) * l];
            let gyrow = &dy[lane * l..(lane + 1) * l];
            let gc = &g_ref[lane * l * n..(lane + 1) * l * n];
            let hc = &hist[lane * l * n..(lane + 1) * l * n];
            let amc = &am[lane * l * n..(lane + 1) * l * n];
            for t in 0..l {
                ddc[0] += gyrow[t] * xrow[t];
                if t > 0 {
                    let grow = &gc[t * n..(t + 1) * n];
                    let hprev = &hc[(t - 1) * n..t * n];
                    let arow_m = &amc[t * n..(t + 1) * n];
                    let dtv = dtrow[t];
                    for nn in 0..n {
                        dac[nn] += grow[nn] * hprev[nn] * dtv * arow_m[nn];
                    }
                }
            }
        }
    });
    for c in 0..d {
        let slot = &colbuf[c * (n + 1)..(c + 1) * (n + 1)];
        out.da[c * n..(c + 1) * n].copy_from_slice(&slot[..n]);
        out.dd[c] = slot[n];
    }

    // Pass 5: dB[b,t,n] = Σ_c g Δ x, dC[b,t,n] = Σ_c dy h — the only
    // reductions across channels; one task per (b, t) slot.
    parallel_chunks_mut(out.dbm, n, threads, |slot, out| {
        let (bi, t) = (slot / l, slot % l);
        out.iter_mut().for_each(|v| *v = 0.0);
        for c in 0..d {
            let lane = bi * d + c;
            let w = dt[lane * l + t] * x[lane * l + t];
            if w != 0.0 {
                let grow = &g_ref[(lane * l + t) * n..(lane * l + t + 1) * n];
                for nn in 0..n {
                    out[nn] += grow[nn] * w;
                }
            }
        }
    });
    parallel_chunks_mut(out.dcm, n, threads, |slot, out| {
        let (bi, t) = (slot / l, slot % l);
        out.iter_mut().for_each(|v| *v = 0.0);
        for c in 0..d {
            let lane = bi * d + c;
            let gy = dy[lane * l + t];
            if gy != 0.0 {
                let hrow = &hist[(lane * l + t) * n..(lane * l + t + 1) * n];
                for nn in 0..n {
                    out[nn] += gy * hrow[nn];
                }
            }
        }
    });
}

/// Packed selective scan backward; returns owned [`SsmGrads`].
#[allow(clippy::too_many_arguments)]
pub fn ssm_packed_bwd(
    x: &[f32],
    dt: &[f32],
    a: &[f32],
    bm: &[f32],
    cm: &[f32],
    dvec: &[f32],
    cache: &ScanCache,
    dy: &[f32],
    dims: Dims,
    threads: usize,
) -> SsmGrads {
    let Dims { b, l, d, n } = dims;
    let mut gr = SsmGrads {
        dx: vec![0.0f32; b * d * l],
        ddt: vec![0.0f32; b * d * l],
        da: vec![0.0f32; d * n],
        dbm: vec![0.0f32; b * l * n],
        dcm: vec![0.0f32; b * l * n],
        dd: vec![0.0f32; d],
    };
    let mut g = vec![0.0f32; b * d * l * n];
    let mut colbuf = vec![0.0f32; d * (n + 1)];
    ssm_packed_bwd_into(
        x,
        dt,
        a,
        bm,
        cm,
        dvec,
        &cache.hist,
        &cache.am,
        dy,
        dims,
        threads,
        SsmGradsMut {
            dx: &mut gr.dx,
            ddt: &mut gr.ddt,
            da: &mut gr.da,
            dbm: &mut gr.dbm,
            dcm: &mut gr.dcm,
            dd: &mut gr.dd,
        },
        &mut g,
        &mut colbuf,
    );
    gr
}

/// Packed selective scan backward **with cross-chunk carry**, into
/// caller buffers.
///
/// Extends [`ssm_packed_bwd_into`] with the state adjoints: `h0` is the
/// carry-in the forward consumed (`(B, D, N)`), `dh_next` is the
/// downstream gradient w.r.t. this chunk's carry-out state (the next
/// chunk's `dh0`; zeros for the stream's final chunk) — it seeds the
/// reverse scan at `t = L-1` — and `dh0` receives the gradient w.r.t.
/// `h0` (`Ā_0 ⊙ g_0`, so nothing flows past a fresh `pos == 0` start).
/// The `t == 0` decay terms of `ddt`/`dA` read `h0` instead of zero.
#[allow(clippy::too_many_arguments)]
pub fn ssm_packed_bwd_carry_into(
    x: &[f32],
    dt: &[f32],
    a: &[f32],
    bm: &[f32],
    cm: &[f32],
    dvec: &[f32],
    hist: &[f32],
    am: &[f32],
    dy: &[f32],
    dims: Dims,
    h0: &[f32],
    dh_next: &[f32],
    threads: usize,
    out: SsmGradsMut<'_>,
    dh0: &mut [f32],
    g: &mut [f32],
    colbuf: &mut [f32],
) {
    let _sp = trace::span(Op::ScanBwd);
    let Dims { b, l, d, n } = dims;
    assert_eq!(dy.len(), b * d * l);
    assert_eq!(hist.len(), b * d * l * n);
    assert_eq!(am.len(), b * d * l * n);
    assert_eq!(h0.len(), b * d * n);
    assert_eq!(dh_next.len(), b * d * n);
    assert_eq!(dh0.len(), b * d * n);
    assert_eq!(g.len(), b * d * l * n);
    assert_eq!(colbuf.len(), d * (n + 1));
    assert_eq!(out.dx.len(), b * d * l);
    assert_eq!(out.ddt.len(), b * d * l);
    assert_eq!(out.da.len(), d * n);
    assert_eq!(out.dbm.len(), b * l * n);
    assert_eq!(out.dcm.len(), b * l * n);
    assert_eq!(out.dd.len(), d);
    let threads = lane_threads(dims, 8 * n, threads);

    // Pass 1: reverse scan for g = dL/dh, seeded with the carry-out
    // adjoint (h_{L-1} is the carry-out, so dh_next adds to g_{L-1}).
    parallel_chunks_mut(g, l * n, threads, |lane, gc| {
        let bi = lane / d;
        let gyrow = &dy[lane * l..(lane + 1) * l];
        let amc = &am[lane * l * n..(lane + 1) * l * n];
        let cmb = &cm[bi * l * n..(bi + 1) * l * n];
        let dhn = &dh_next[lane * n..(lane + 1) * n];
        for t in (0..l).rev() {
            let gy = gyrow[t];
            let crow = &cmb[t * n..(t + 1) * n];
            let (cur, done) = gc.split_at_mut((t + 1) * n);
            let grow = &mut cur[t * n..];
            if t + 1 == l {
                for nn in 0..n {
                    grow[nn] = gy * crow[nn] + dhn[nn];
                }
            } else {
                let gnext = &done[..n];
                let anext = &amc[(t + 1) * n..(t + 2) * n];
                for nn in 0..n {
                    grow[nn] = gy * crow[nn] + anext[nn] * gnext[nn];
                }
            }
        }
    });
    let g_ref = &*g;

    // Carry-in adjoint: dh0 = Ā_0 ⊙ g_0 (the mask inside Ā keeps fresh
    // starts from leaking gradient into the previous chunk).
    for lane in 0..b * d {
        let amc = &am[lane * l * n..lane * l * n + n];
        let g0 = &g_ref[lane * l * n..lane * l * n + n];
        let orow = &mut dh0[lane * n..(lane + 1) * n];
        for nn in 0..n {
            orow[nn] = amc[nn] * g0[nn];
        }
    }

    // Pass 2: dx_t = D·dy_t + Σ_n g_t Δ_t B_t — unchanged.
    parallel_chunks_mut(out.dx, l, threads, |lane, out| {
        let (bi, c) = (lane / d, lane % d);
        let gyrow = &dy[lane * l..(lane + 1) * l];
        let dtrow = &dt[lane * l..(lane + 1) * l];
        let gc = &g_ref[lane * l * n..(lane + 1) * l * n];
        let bmb = &bm[bi * l * n..(bi + 1) * l * n];
        let dv = dvec[c];
        for t in 0..l {
            let brow = &bmb[t * n..(t + 1) * n];
            let grow = &gc[t * n..(t + 1) * n];
            let mut dot = 0.0f32;
            for nn in 0..n {
                dot += grow[nn] * brow[nn];
            }
            out[t] = dv * gyrow[t] + dot * dtrow[t];
        }
    });

    // Pass 3: ddt — the t == 0 decay term reads h0 (zero without carry).
    parallel_chunks_mut(out.ddt, l, threads, |lane, out| {
        let (bi, c) = (lane / d, lane % d);
        let xrow = &x[lane * l..(lane + 1) * l];
        let arow = &a[c * n..(c + 1) * n];
        let gc = &g_ref[lane * l * n..(lane + 1) * l * n];
        let hc = &hist[lane * l * n..(lane + 1) * l * n];
        let amc = &am[lane * l * n..(lane + 1) * l * n];
        let bmb = &bm[bi * l * n..(bi + 1) * l * n];
        let h0c = &h0[lane * n..(lane + 1) * n];
        for t in 0..l {
            let brow = &bmb[t * n..(t + 1) * n];
            let grow = &gc[t * n..(t + 1) * n];
            let arow_m = &amc[t * n..(t + 1) * n];
            let hprev: &[f32] = if t > 0 { &hc[(t - 1) * n..t * n] } else { h0c };
            let mut acc = 0.0f32;
            for nn in 0..n {
                acc += grow[nn] * hprev[nn] * arow[nn] * arow_m[nn];
            }
            let mut dot = 0.0f32;
            for nn in 0..n {
                dot += grow[nn] * brow[nn];
            }
            out[t] = acc + dot * xrow[t];
        }
    });

    // Pass 4: dA / dD reductions — t == 0 reads h0 as well.
    parallel_chunks_mut(colbuf, n + 1, threads, |c, slot| {
        slot.iter_mut().for_each(|v| *v = 0.0);
        let (dac, ddc) = slot.split_at_mut(n);
        for bi in 0..b {
            let lane = bi * d + c;
            let xrow = &x[lane * l..(lane + 1) * l];
            let dtrow = &dt[lane * l..(lane + 1) * l];
            let gyrow = &dy[lane * l..(lane + 1) * l];
            let gc = &g_ref[lane * l * n..(lane + 1) * l * n];
            let hc = &hist[lane * l * n..(lane + 1) * l * n];
            let amc = &am[lane * l * n..(lane + 1) * l * n];
            let h0c = &h0[lane * n..(lane + 1) * n];
            for t in 0..l {
                ddc[0] += gyrow[t] * xrow[t];
                let grow = &gc[t * n..(t + 1) * n];
                let arow_m = &amc[t * n..(t + 1) * n];
                let hprev: &[f32] = if t > 0 { &hc[(t - 1) * n..t * n] } else { h0c };
                let dtv = dtrow[t];
                for nn in 0..n {
                    dac[nn] += grow[nn] * hprev[nn] * dtv * arow_m[nn];
                }
            }
        }
    });
    for c in 0..d {
        let slot = &colbuf[c * (n + 1)..(c + 1) * (n + 1)];
        out.da[c * n..(c + 1) * n].copy_from_slice(&slot[..n]);
        out.dd[c] = slot[n];
    }

    // Pass 5: dB / dC — unchanged.
    parallel_chunks_mut(out.dbm, n, threads, |slot, out| {
        let (bi, t) = (slot / l, slot % l);
        out.iter_mut().for_each(|v| *v = 0.0);
        for c in 0..d {
            let lane = bi * d + c;
            let w = dt[lane * l + t] * x[lane * l + t];
            if w != 0.0 {
                let grow = &g_ref[(lane * l + t) * n..(lane * l + t + 1) * n];
                for nn in 0..n {
                    out[nn] += grow[nn] * w;
                }
            }
        }
    });
    parallel_chunks_mut(out.dcm, n, threads, |slot, out| {
        let (bi, t) = (slot / l, slot % l);
        out.iter_mut().for_each(|v| *v = 0.0);
        for c in 0..d {
            let lane = bi * d + c;
            let gy = dy[lane * l + t];
            if gy != 0.0 {
                let hrow = &hist[(lane * l + t) * n..(lane * l + t + 1) * n];
                for nn in 0..n {
                    out[nn] += gy * hrow[nn];
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::position_indices;
    use crate::util::rng::Pcg64;

    fn randv(rng: &mut Pcg64, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| scale * (rng.next_f32() - 0.5)).collect()
    }

    /// Serial per-sequence conv reference (no packing): each segment run
    /// independently with plain causal semantics.
    fn conv_per_sequence(
        x: &[f32],
        lens: &[usize],
        l: usize,
        d: usize,
        w: &[f32],
        wlen: usize,
        bias: &[f32],
    ) -> Vec<f32> {
        // x channel-major (1, D, L) single row
        let mut y = vec![0.0f32; d * l];
        let mut segs: Vec<(usize, usize)> = Vec::new();
        let mut off = 0;
        for &nl in lens {
            segs.push((off, nl));
            off += nl;
        }
        if off < l {
            segs.push((off, l - off)); // padding tail is its own segment
        }
        for c in 0..d {
            for &(s0, sl) in &segs {
                for t in 0..sl {
                    let mut acc = bias[c];
                    for j in 0..wlen {
                        let shift = wlen - 1 - j;
                        if t >= shift {
                            acc += w[j * d + c] * x[c * l + s0 + t - shift];
                        }
                    }
                    y[c * l + s0 + t] = acc;
                }
            }
        }
        y
    }

    #[test]
    fn conv_packed_equals_per_sequence() {
        let (l, d, wlen) = (24, 3, 4);
        let lens = [7usize, 9, 5]; // + 3 padding
        let pos = position_indices(&lens, l);
        let mut rng = Pcg64::new(5, 0);
        let x = randv(&mut rng, d * l, 2.0);
        let w = randv(&mut rng, wlen * d, 1.0);
        let bias = randv(&mut rng, d, 1.0);
        let dims = Dims { b: 1, l, d, n: 1 };
        let y = conv1d_packed_fwd(&x, dims, &w, wlen, &bias, &pos, 1);
        let yref = conv_per_sequence(&x, &lens, l, d, &w, wlen, &bias);
        for (a, b) in y.iter().zip(&yref) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn conv_backward_matches_finite_differences() {
        let (l, d, wlen) = (10, 2, 3);
        let lens = [4usize, 3];
        let pos = position_indices(&lens, l);
        let mut rng = Pcg64::new(9, 0);
        let x = randv(&mut rng, d * l, 1.0);
        let w = randv(&mut rng, wlen * d, 1.0);
        let bias = randv(&mut rng, d, 1.0);
        let gy = randv(&mut rng, d * l, 1.0);
        let dims = Dims { b: 1, l, d, n: 1 };
        let obj = |x: &[f32], w: &[f32], bias: &[f32]| -> f32 {
            conv1d_packed_fwd(x, dims, w, wlen, bias, &pos, 1)
                .iter()
                .zip(&gy)
                .map(|(a, b)| a * b)
                .sum()
        };
        let (dx, dw, db) = conv1d_packed_bwd(&x, dims, &w, wlen, &pos, &gy, 1);
        let h = 1e-3;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp[i] += h;
            let mut xm = x.clone();
            xm[i] -= h;
            let fd = (obj(&xp, &w, &bias) - obj(&xm, &w, &bias)) / (2.0 * h);
            assert!((fd - dx[i]).abs() < 1e-2, "dx[{i}] fd {fd} an {}", dx[i]);
        }
        for i in 0..w.len() {
            let mut wp = w.clone();
            wp[i] += h;
            let mut wm = w.clone();
            wm[i] -= h;
            let fd = (obj(&x, &wp, &bias) - obj(&x, &wm, &bias)) / (2.0 * h);
            assert!((fd - dw[i]).abs() < 1e-2, "dw[{i}] fd {fd} an {}", dw[i]);
        }
        for i in 0..bias.len() {
            let mut bp = bias.clone();
            bp[i] += h;
            let mut bm2 = bias.clone();
            bm2[i] -= h;
            let fd = (obj(&x, &w, &bp) - obj(&x, &w, &bm2)) / (2.0 * h);
            assert!((fd - db[i]).abs() < 1e-2, "db[{i}] fd {fd} an {}", db[i]);
        }
    }

    /// Serial unpacked scan oracle over one segment.
    #[allow(clippy::too_many_arguments)]
    fn ssm_segment(
        x: &[f32],
        dt: &[f32],
        a: &[f32],
        bm: &[f32],
        cm: &[f32],
        dvec: &[f32],
        d: usize,
        n: usize,
        sl: usize,
    ) -> Vec<f32> {
        // x, dt: (D, sl) channel-major; bm, cm: (sl, N)
        let mut y = vec![0.0f32; d * sl];
        for c in 0..d {
            let mut hstate = vec![0.0f32; n];
            for t in 0..sl {
                let dtv = dt[c * sl + t];
                let xv = x[c * sl + t];
                for nn in 0..n {
                    let av = (dtv * a[c * n + nn]).exp();
                    hstate[nn] = if t == 0 {
                        dtv * xv * bm[t * n + nn]
                    } else {
                        av * hstate[nn] + dtv * xv * bm[t * n + nn]
                    };
                }
                let mut acc = dvec[c] * xv;
                for nn in 0..n {
                    acc += cm[t * n + nn] * hstate[nn];
                }
                y[c * sl + t] = acc;
            }
        }
        y
    }

    #[test]
    fn scan_packed_equals_per_sequence() {
        let (l, d, n) = (20, 3, 4);
        let lens = [8usize, 7, 5]; // exactly full row
        let pos = position_indices(&lens, l);
        let mut rng = Pcg64::new(11, 0);
        let x = randv(&mut rng, d * l, 1.0);
        let dt: Vec<f32> = randv(&mut rng, d * l, 1.0)
            .into_iter()
            .map(|v| v.abs() + 0.05)
            .collect();
        let a: Vec<f32> = randv(&mut rng, d * n, 1.0)
            .into_iter()
            .map(|v| -(v.abs() + 0.1))
            .collect();
        let bm = randv(&mut rng, l * n, 1.0);
        let cm = randv(&mut rng, l * n, 1.0);
        let dvec = randv(&mut rng, d, 1.0);
        let dims = Dims { b: 1, l, d, n };
        let (y, _) = ssm_packed_fwd(&x, &dt, &a, &bm, &cm, &dvec, &pos, dims, 1);
        // the fused forward-only variant must agree exactly
        let y_nc = ssm_packed_fwd_nocache(&x, &dt, &a, &bm, &cm, &dvec, &pos, dims, 1);
        assert_eq!(y, y_nc, "nocache forward diverged from cached forward");

        let mut off = 0;
        for &sl in &lens {
            // slice out the segment, per channel
            let mut xs = vec![0.0f32; d * sl];
            let mut dts = vec![0.0f32; d * sl];
            for c in 0..d {
                for t in 0..sl {
                    xs[c * sl + t] = x[c * l + off + t];
                    dts[c * sl + t] = dt[c * l + off + t];
                }
            }
            let bms = bm[off * n..(off + sl) * n].to_vec();
            let cms = cm[off * n..(off + sl) * n].to_vec();
            let yref = ssm_segment(&xs, &dts, &a, &bms, &cms, &dvec, d, n, sl);
            for c in 0..d {
                for t in 0..sl {
                    let got = y[c * l + off + t];
                    let want = yref[c * sl + t];
                    assert!(
                        (got - want).abs() < 1e-5,
                        "seg@{off} c{c} t{t}: {got} vs {want}"
                    );
                }
            }
            off += sl;
        }
    }

    #[test]
    fn scan_backward_matches_finite_differences() {
        let (l, d, n) = (9, 2, 3);
        let lens = [5usize, 3];
        let pos = position_indices(&lens, l);
        let mut rng = Pcg64::new(13, 0);
        let x = randv(&mut rng, d * l, 1.0);
        let dt: Vec<f32> = randv(&mut rng, d * l, 1.0)
            .into_iter()
            .map(|v| v.abs() + 0.05)
            .collect();
        let a: Vec<f32> = randv(&mut rng, d * n, 1.0)
            .into_iter()
            .map(|v| -(v.abs() + 0.1))
            .collect();
        let bm = randv(&mut rng, l * n, 1.0);
        let cm = randv(&mut rng, l * n, 1.0);
        let dvec = randv(&mut rng, d, 1.0);
        let gy = randv(&mut rng, d * l, 1.0);
        let dims = Dims { b: 1, l, d, n };

        let obj = |x: &[f32], dt: &[f32], a: &[f32], bm: &[f32], cm: &[f32], dvec: &[f32]| -> f32 {
            let (y, _) = ssm_packed_fwd(x, dt, a, bm, cm, dvec, &pos, dims, 1);
            y.iter().zip(&gy).map(|(p, q)| p * q).sum()
        };
        let (y0, cache) = ssm_packed_fwd(&x, &dt, &a, &bm, &cm, &dvec, &pos, dims, 1);
        let _ = y0;
        let gr = ssm_packed_bwd(&x, &dt, &a, &bm, &cm, &dvec, &cache, &gy, dims, 1);

        let h = 1e-3;
        let check = |name: &str, vals: &[f32], an: &[f32], f: &dyn Fn(&[f32]) -> f32| {
            for i in 0..vals.len() {
                let mut vp = vals.to_vec();
                vp[i] += h;
                let mut vm = vals.to_vec();
                vm[i] -= h;
                let fd = (f(&vp) - f(&vm)) / (2.0 * h);
                assert!(
                    (fd - an[i]).abs() < 2e-2_f32.max(0.02 * fd.abs()),
                    "{name}[{i}] fd {fd} an {}",
                    an[i]
                );
            }
        };
        check("dx", &x, &gr.dx, &|v| obj(v, &dt, &a, &bm, &cm, &dvec));
        check("ddt", &dt, &gr.ddt, &|v| obj(&x, v, &a, &bm, &cm, &dvec));
        check("da", &a, &gr.da, &|v| obj(&x, &dt, v, &bm, &cm, &dvec));
        check("dbm", &bm, &gr.dbm, &|v| obj(&x, &dt, &a, v, &cm, &dvec));
        check("dcm", &cm, &gr.dcm, &|v| obj(&x, &dt, &a, &bm, v, &dvec));
        check("dd", &dvec, &gr.dd, &|v| obj(&x, &dt, &a, &bm, &cm, v));
    }

    /// Gather chunk `[c0, c1)` of a channel-major `(1, D, L)` plane.
    fn slice_cm(x: &[f32], d: usize, l: usize, c0: usize, c1: usize) -> Vec<f32> {
        let cl = c1 - c0;
        let mut out = vec![0.0f32; d * cl];
        for c in 0..d {
            out[c * cl..(c + 1) * cl].copy_from_slice(&x[c * l + c0..c * l + c1]);
        }
        out
    }

    /// Scatter chunk `[c0, c1)` back into a channel-major `(1, D, L)` plane.
    fn unslice_cm(dst: &mut [f32], chunk: &[f32], d: usize, l: usize, c0: usize, c1: usize) {
        let cl = c1 - c0;
        for c in 0..d {
            dst[c * l + c0..c * l + c1].copy_from_slice(&chunk[c * cl..(c + 1) * cl]);
        }
    }

    const CHUNK_CUTS: [usize; 5] = [0, 5, 6, 13, 20];

    #[test]
    fn conv_carry_chunks_match_monolithic() {
        // Chunked conv with tail carry over cuts {5,1,7,7} (including a
        // length-1 chunk) must reproduce the monolithic packed conv —
        // forward and backward — on a row with interior sequence starts.
        let (l, d, wlen) = (20usize, 3usize, 4usize);
        let tw = wlen - 1;
        let lens = [8usize, 7, 5];
        let pos = position_indices(&lens, l);
        let mut rng = Pcg64::new(21, 0);
        let x = randv(&mut rng, d * l, 1.5);
        let w = randv(&mut rng, wlen * d, 1.0);
        let bias = randv(&mut rng, d, 1.0);
        let gy = randv(&mut rng, d * l, 1.0);
        let dims = Dims { b: 1, l, d, n: 1 };
        let y_full = conv1d_packed_fwd(&x, dims, &w, wlen, &bias, &pos, 1);
        let (dx_full, dw_full, db_full) = conv1d_packed_bwd(&x, dims, &w, wlen, &pos, &gy, 1);

        // forward over chunks, saving each chunk's carry-in tail
        let mut y_chunked = vec![0.0f32; d * l];
        let mut tails: Vec<Vec<f32>> = vec![vec![0.0f32; d * tw]];
        for win in CHUNK_CUTS.windows(2) {
            let (c0, c1) = (win[0], win[1]);
            let cl = c1 - c0;
            let cdims = Dims { b: 1, l: cl, d, n: 1 };
            let xc = slice_cm(&x, d, l, c0, c1);
            let mut yc = vec![0.0f32; d * cl];
            let mut tail_out = vec![0.0f32; d * tw];
            conv1d_packed_fwd_carry_into(
                &xc,
                cdims,
                &w,
                wlen,
                &bias,
                &pos[c0..c1],
                tails.last().unwrap(),
                1,
                &mut yc,
                &mut tail_out,
            );
            unslice_cm(&mut y_chunked, &yc, d, l, c0, c1);
            tails.push(tail_out);
        }
        for (a, b) in y_full.iter().zip(&y_chunked) {
            assert!((a - b).abs() < 1e-6, "fwd {a} vs {b}");
        }

        // backward over chunks in reverse, carrying the tail adjoint
        let mut dx_chunked = vec![0.0f32; d * l];
        let mut dw_acc = vec![0.0f32; wlen * d];
        let mut db_acc = vec![0.0f32; d];
        let mut dtail_next = vec![0.0f32; d * tw];
        for (k, win) in CHUNK_CUTS.windows(2).enumerate().rev() {
            let (c0, c1) = (win[0], win[1]);
            let cl = c1 - c0;
            let cdims = Dims { b: 1, l: cl, d, n: 1 };
            let xc = slice_cm(&x, d, l, c0, c1);
            let gyc = slice_cm(&gy, d, l, c0, c1);
            let mut dxc = vec![0.0f32; d * cl];
            let mut dtail_out = vec![0.0f32; d * tw];
            let mut colbuf = vec![0.0f32; d * (wlen + 1)];
            conv1d_packed_bwd_carry_into(
                &xc,
                cdims,
                &w,
                wlen,
                &pos[c0..c1],
                &tails[k],
                &gyc,
                &dtail_next,
                1,
                &mut dxc,
                &mut dw_acc,
                &mut db_acc,
                &mut dtail_out,
                &mut colbuf,
            );
            unslice_cm(&mut dx_chunked, &dxc, d, l, c0, c1);
            dtail_next = dtail_out;
        }
        for (a, b) in dx_full.iter().zip(&dx_chunked) {
            assert!((a - b).abs() < 1e-5, "dx {a} vs {b}");
        }
        for (a, b) in dw_full.iter().zip(&dw_acc) {
            assert!((a - b).abs() < 1e-5, "dw {a} vs {b}");
        }
        for (a, b) in db_full.iter().zip(&db_acc) {
            assert!((a - b).abs() < 1e-5, "db {a} vs {b}");
        }
        // the stream starts fresh: no gradient may leak before it
        assert!(dtail_next.iter().all(|&v| v == 0.0), "{dtail_next:?}");
    }

    #[test]
    fn scan_carry_chunks_match_monolithic() {
        // Same cuts for the selective scan: state carry forward, g-seed
        // + h0-read backward must reproduce the monolithic gradients.
        let (l, d, n) = (20usize, 2usize, 3usize);
        let lens = [8usize, 7, 5];
        let pos = position_indices(&lens, l);
        let mut rng = Pcg64::new(23, 0);
        let x = randv(&mut rng, d * l, 1.0);
        let dt: Vec<f32> = randv(&mut rng, d * l, 1.0)
            .into_iter()
            .map(|v| v.abs() + 0.05)
            .collect();
        let a: Vec<f32> = randv(&mut rng, d * n, 1.0)
            .into_iter()
            .map(|v| -(v.abs() + 0.1))
            .collect();
        let bm = randv(&mut rng, l * n, 1.0);
        let cm = randv(&mut rng, l * n, 1.0);
        let dvec = randv(&mut rng, d, 1.0);
        let gy = randv(&mut rng, d * l, 1.0);
        let dims = Dims { b: 1, l, d, n };
        let (y_full, cache) = ssm_packed_fwd(&x, &dt, &a, &bm, &cm, &dvec, &pos, dims, 1);
        let gr_full = ssm_packed_bwd(&x, &dt, &a, &bm, &cm, &dvec, &cache, &gy, dims, 1);

        // forward over chunks, saving carry-in states and chunk caches
        let mut y_chunked = vec![0.0f32; d * l];
        let mut states: Vec<Vec<f32>> = vec![vec![0.0f32; d * n]];
        let mut hists: Vec<Vec<f32>> = Vec::new();
        let mut ams: Vec<Vec<f32>> = Vec::new();
        for win in CHUNK_CUTS.windows(2) {
            let (c0, c1) = (win[0], win[1]);
            let cl = c1 - c0;
            let cdims = Dims { b: 1, l: cl, d, n };
            let xc = slice_cm(&x, d, l, c0, c1);
            let dtc = slice_cm(&dt, d, l, c0, c1);
            let mut yc = vec![0.0f32; d * cl];
            let mut hist = vec![0.0f32; d * cl * n];
            let mut am = vec![0.0f32; d * cl * n];
            let mut h_out = vec![0.0f32; d * n];
            ssm_packed_fwd_carry_into(
                &xc,
                &dtc,
                &a,
                &bm[c0 * n..c1 * n],
                &cm[c0 * n..c1 * n],
                &dvec,
                &pos[c0..c1],
                cdims,
                states.last().unwrap(),
                1,
                &mut yc,
                &mut hist,
                &mut am,
                &mut h_out,
            );
            unslice_cm(&mut y_chunked, &yc, d, l, c0, c1);
            states.push(h_out);
            hists.push(hist);
            ams.push(am);
        }
        for (a1, b1) in y_full.iter().zip(&y_chunked) {
            assert!((a1 - b1).abs() < 1e-6, "fwd {a1} vs {b1}");
        }

        // backward over chunks in reverse, carrying the state adjoint
        let mut dx_c = vec![0.0f32; d * l];
        let mut ddt_c = vec![0.0f32; d * l];
        let mut da_c = vec![0.0f32; d * n];
        let mut dbm_c = vec![0.0f32; l * n];
        let mut dcm_c = vec![0.0f32; l * n];
        let mut dd_c = vec![0.0f32; d];
        let mut dh_next = vec![0.0f32; d * n];
        for (k, win) in CHUNK_CUTS.windows(2).enumerate().rev() {
            let (c0, c1) = (win[0], win[1]);
            let cl = c1 - c0;
            let cdims = Dims { b: 1, l: cl, d, n };
            let xc = slice_cm(&x, d, l, c0, c1);
            let dtc = slice_cm(&dt, d, l, c0, c1);
            let gyc = slice_cm(&gy, d, l, c0, c1);
            let mut dx = vec![0.0f32; d * cl];
            let mut ddt = vec![0.0f32; d * cl];
            let mut da = vec![0.0f32; d * n];
            let mut dbm = vec![0.0f32; cl * n];
            let mut dcm = vec![0.0f32; cl * n];
            let mut dd = vec![0.0f32; d];
            let mut dh0 = vec![0.0f32; d * n];
            let mut g = vec![0.0f32; d * cl * n];
            let mut colbuf = vec![0.0f32; d * (n + 1)];
            ssm_packed_bwd_carry_into(
                &xc,
                &dtc,
                &a,
                &bm[c0 * n..c1 * n],
                &cm[c0 * n..c1 * n],
                &dvec,
                &hists[k],
                &ams[k],
                &gyc,
                cdims,
                &states[k],
                &dh_next,
                1,
                SsmGradsMut {
                    dx: &mut dx,
                    ddt: &mut ddt,
                    da: &mut da,
                    dbm: &mut dbm,
                    dcm: &mut dcm,
                    dd: &mut dd,
                },
                &mut dh0,
                &mut g,
                &mut colbuf,
            );
            unslice_cm(&mut dx_c, &dx, d, l, c0, c1);
            unslice_cm(&mut ddt_c, &ddt, d, l, c0, c1);
            dbm_c[c0 * n..c1 * n].copy_from_slice(&dbm);
            dcm_c[c0 * n..c1 * n].copy_from_slice(&dcm);
            for i in 0..d * n {
                da_c[i] += da[i];
            }
            for i in 0..d {
                dd_c[i] += dd[i];
            }
            dh_next = dh0;
        }
        let close = |name: &str, got: &[f32], want: &[f32]| {
            for (i, (g1, w1)) in got.iter().zip(want).enumerate() {
                assert!(
                    (g1 - w1).abs() < 1e-4_f32.max(1e-4 * w1.abs()),
                    "{name}[{i}]: {g1} vs {w1}"
                );
            }
        };
        close("dx", &dx_c, &gr_full.dx);
        close("ddt", &ddt_c, &gr_full.ddt);
        close("da", &da_c, &gr_full.da);
        close("dbm", &dbm_c, &gr_full.dbm);
        close("dcm", &dcm_c, &gr_full.dcm);
        close("dd", &dd_c, &gr_full.dd);
        assert!(dh_next.iter().all(|&v| v == 0.0), "{dh_next:?}");
    }

    #[test]
    fn junk_carry_is_masked_at_fresh_starts() {
        // A chunk whose first slot has pos == 0 must ignore arbitrary
        // carried state entirely — conv and scan (the §5 composition of
        // chunk-boundary carry with sequence-boundary isolation).
        let (l, d, n, wlen) = (12usize, 2usize, 3usize, 4usize);
        let tw = wlen - 1;
        let lens = [7usize, 5];
        let pos = position_indices(&lens, l);
        let mut rng = Pcg64::new(29, 0);
        let x = randv(&mut rng, d * l, 1.0);
        let w = randv(&mut rng, wlen * d, 1.0);
        let bias = randv(&mut rng, d, 1.0);
        let dims = Dims { b: 1, l, d, n };
        let zero_tail = vec![0.0f32; d * tw];
        let junk_tail = vec![37.0f32; d * tw];
        let run_conv = |tail: &[f32]| {
            let mut y = vec![0.0f32; d * l];
            let mut t_out = vec![0.0f32; d * tw];
            conv1d_packed_fwd_carry_into(
                &x,
                dims,
                &w,
                wlen,
                &bias,
                &pos,
                tail,
                1,
                &mut y,
                &mut t_out,
            );
            y
        };
        assert_eq!(run_conv(&zero_tail), run_conv(&junk_tail));
        // and the carry-free kernel agrees with zero-state carry
        assert_eq!(
            run_conv(&zero_tail),
            conv1d_packed_fwd(&x, dims, &w, wlen, &bias, &pos, 1)
        );

        let dt: Vec<f32> = randv(&mut rng, d * l, 1.0)
            .into_iter()
            .map(|v| v.abs() + 0.05)
            .collect();
        let a: Vec<f32> = vec![-0.4; d * n];
        let bm = randv(&mut rng, l * n, 1.0);
        let cm = randv(&mut rng, l * n, 1.0);
        let dvec = vec![0.5; d];
        let run_scan = |h0: &[f32]| {
            let mut y = vec![0.0f32; d * l];
            let mut hist = vec![0.0f32; d * l * n];
            let mut am = vec![0.0f32; d * l * n];
            let mut h_out = vec![0.0f32; d * n];
            ssm_packed_fwd_carry_into(
                &x,
                &dt,
                &a,
                &bm,
                &cm,
                &dvec,
                &pos,
                dims,
                h0,
                1,
                &mut y,
                &mut hist,
                &mut am,
                &mut h_out,
            );
            y
        };
        let zero_h = vec![0.0f32; d * n];
        let junk_h = vec![-11.0f32; d * n];
        assert_eq!(run_scan(&zero_h), run_scan(&junk_h));
        let (y_plain, _) = ssm_packed_fwd(&x, &dt, &a, &bm, &cm, &dvec, &pos, dims, 1);
        assert_eq!(run_scan(&zero_h), y_plain);
    }

    #[test]
    fn no_state_crosses_boundaries() {
        // Changing tokens of the FIRST sequence must not change scan
        // outputs of the SECOND (the PUI isolation property, op-level).
        let (l, d, n) = (16, 2, 3);
        let lens = [8usize, 8];
        let pos = position_indices(&lens, l);
        let mut rng = Pcg64::new(17, 0);
        let mut x = randv(&mut rng, d * l, 1.0);
        let dt: Vec<f32> = randv(&mut rng, d * l, 1.0)
            .into_iter()
            .map(|v| v.abs() + 0.05)
            .collect();
        let a: Vec<f32> = vec![-0.5; d * n];
        let bm = randv(&mut rng, l * n, 1.0);
        let cm = randv(&mut rng, l * n, 1.0);
        let dvec = vec![1.0; d];
        let dims = Dims { b: 1, l, d, n };
        let (y1, _) = ssm_packed_fwd(&x, &dt, &a, &bm, &cm, &dvec, &pos, dims, 1);
        for t in 0..8 {
            x[t] += 3.0; // perturb channel 0 of the first sequence
        }
        let (y2, _) = ssm_packed_fwd(&x, &dt, &a, &bm, &cm, &dvec, &pos, dims, 1);
        for c in 0..d {
            for t in 8..16 {
                assert_eq!(y1[c * l + t], y2[c * l + t], "leak at c{c} t{t}");
            }
        }
        assert_ne!(y1[0], y2[0]);
    }
}
