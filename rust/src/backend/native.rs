//! The default backend: pure-Rust packed Mamba training on the host CPU.
//!
//! No artifacts, no FFI — [`model`] implements the forward/backward and
//! [`kernels`](super::kernels) the paper's packed operators, parallelized
//! over rows and channels via the persistent `util::threadpool`
//! [`WorkerPool`](crate::util::threadpool::WorkerPool); the GEMM-shaped
//! ops run on the blocked micro-kernel in [`gemm`](super::gemm), whose
//! register tile is runtime-dispatched (`PACKMAMBA_GEMM`, resolved once
//! at backend construction).  Thread count is a **constructor
//! parameter** ([`NativeBackend::with_threads`]); [`NativeBackend::new`]
//! defaults it from `PACKMAMBA_THREADS` or the machine's available
//! parallelism ([`NativeBackend::env_threads`]) — resolved at
//! construction, so benches sweeping thread counts pass them explicitly
//! instead of mutating the env mid-process.  The numerics are
//! bit-identical for any thread count, which keeps data-parallel
//! replicas exactly in sync.
//!
//! The backend owns a persistent [`model::ModelWorkspace`] (buffer arena
//! + GEMM scratch), spec-sized gradient buffers, and pre-warmed pool
//! workers, so the fused [`Backend::train_step`] performs **zero heap
//! allocations and zero thread spawns** after the first (warmup) step —
//! single- *and* multi-threaded; see `tests/zero_alloc.rs`.

use std::cell::{Cell, Ref, RefCell};
use std::collections::HashMap;
use std::time::Instant;

use crate::config::{BackendKind, ModelConfig, Scheme, TrainConfig};
use crate::packing::PackedBatch;
use crate::runtime::{ExecStats, ParamSpec};
use crate::tensor::Tensor;
use crate::util::failpoint;
use crate::util::trace::{self, Op};
use crate::Result;

use super::adamw::{self, AdamWConfig};
use super::{
    model, native_buckets, ops, params, Backend, BatchGeometry, CarryState, TrainState,
};

/// Default ceiling on consecutive non-finite steps before the guard
/// aborts (overridden from `TrainConfig::max_bad_steps` by
/// `backend::create`).
pub const DEFAULT_MAX_BAD_STEPS: usize = 3;

/// Typed fail-fast error: even the cheapest (recomputed) chunked
/// execution mode cannot fit the configured activation memory budget.
/// Raised at the **ensure phase** — before any chunk executes — so an
/// over-budget run never dies mid-step; callers can
/// `downcast_ref::<MemBudgetExceeded>()` through any context frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemBudgetExceeded {
    /// Bytes the recomputed chunked step needs live.
    pub needed_bytes: usize,
    /// The configured `--mem-budget` / `PACKMAMBA_MEM_BUDGET` ceiling.
    pub budget_bytes: usize,
}

impl std::fmt::Display for MemBudgetExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "activation memory budget exceeded: recomputed chunked execution \
             needs {} bytes but the budget is {} bytes ({} bytes short) — \
             raise --mem-budget / PACKMAMBA_MEM_BUDGET or shrink --chunk-len",
            self.needed_bytes,
            self.budget_bytes,
            self.needed_bytes - self.budget_bytes
        )
    }
}

impl std::error::Error for MemBudgetExceeded {}

pub struct NativeBackend {
    threads: usize,
    opt: AdamWConfig,
    stats: RefCell<HashMap<String, ExecStats>>,
    /// Arena + layer caches + GEMM scratch, reused every step.
    ws: RefCell<model::ModelWorkspace>,
    /// Spec-sized gradient buffers for the fused step.
    grad_bufs: RefCell<Vec<Vec<f32>>>,
    /// Param specs for the model last seen (spec building allocates
    /// names; caching keeps the steady-state step allocation-free).
    specs_cache: RefCell<Option<(ModelConfig, Vec<ParamSpec>)>>,
    /// Stream-end carry of the last chunked train step (paper §5), one
    /// lane per stream of the batch it served: reused as the next step's
    /// stream-start state — truncated BPTT at batch boundaries, so
    /// sequences the packer split across batches continue with real
    /// state.  Fresh `pos == 0` starts discard it via the boundary mask;
    /// a batch whose stream partition no longer matches (e.g. the
    /// packer's final undersized flush batch collapsing to fewer
    /// streams) resets it to zeros instead of reusing stale lanes; reset
    /// explicitly with [`NativeBackend::reset_chunk_carry`].
    chunk_carry: RefCell<Option<model::ChunkState>>,
    /// Consecutive steps whose update the non-finite guard skipped; a
    /// clean step resets it, reaching `max_bad_steps` aborts the run.
    bad_steps: Cell<usize>,
    /// Abort threshold for `bad_steps` (config: `max_bad_steps`).
    max_bad_steps: Cell<usize>,
    /// Chunked activation mode: recompute (checkpoint only carry states,
    /// rebuild caches in the backward) vs cache-everything.  Set from
    /// `TrainConfig::recompute` by `backend::create`; the budget sizing
    /// in [`NativeBackend::ensure_chunked`] may raise it (degradation).
    recompute: Cell<bool>,
    /// Activation memory budget in bytes (0 = unlimited; config:
    /// `mem_budget`), enforced at the chunked ensure phase.
    mem_budget: Cell<usize>,
    /// Whether the budget degradation warning has been logged (once).
    degraded_logged: Cell<bool>,
}

impl NativeBackend {
    /// Backend with [`NativeBackend::env_threads`] workers.
    pub fn new() -> NativeBackend {
        Self::with_threads(Self::env_threads())
    }

    /// The environment's default thread count: `PACKMAMBA_THREADS`, else
    /// the machine's available parallelism.  Read at **construction
    /// only** — callers that sweep thread counts (benches, dp workers)
    /// pass explicit values to [`NativeBackend::with_threads`] instead
    /// of mutating the env mid-process.
    pub fn env_threads() -> usize {
        std::env::var("PACKMAMBA_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&t| t >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            })
    }

    /// Backend pinned to exactly `threads` participants.  Construction
    /// is where the hot path's one-time setup happens: the persistent
    /// worker pool is grown to `threads - 1` parked workers (so the
    /// first train step spawns nothing) and the GEMM dispatch tier is
    /// resolved from `PACKMAMBA_GEMM` + CPUID.
    pub fn with_threads(threads: usize) -> NativeBackend {
        let threads = threads.max(1);
        crate::util::threadpool::WorkerPool::global().ensure_workers(threads.saturating_sub(1));
        // resolve the GEMM tier eagerly — not inside the log macro, whose
        // arguments a level-gated logger may never evaluate
        let tier = super::gemm::detected_mode();
        log::debug!("native backend: {threads} threads, gemm dispatch tier `{}`", tier.name());
        NativeBackend {
            threads,
            opt: AdamWConfig::default(),
            stats: RefCell::new(HashMap::new()),
            ws: RefCell::new(model::ModelWorkspace::new()),
            grad_bufs: RefCell::new(Vec::new()),
            specs_cache: RefCell::new(None),
            chunk_carry: RefCell::new(None),
            bad_steps: Cell::new(0),
            max_bad_steps: Cell::new(DEFAULT_MAX_BAD_STEPS),
            recompute: Cell::new(false),
            mem_budget: Cell::new(0),
            degraded_logged: Cell::new(false),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Set the consecutive non-finite-step abort threshold (see
    /// `TrainConfig::max_bad_steps`; clamped to >= 1).
    pub fn set_max_bad_steps(&self, k: usize) {
        self.max_bad_steps.set(k.max(1));
    }

    /// Select the chunked step's activation mode (see
    /// `TrainConfig::recompute`).  May also be raised at the ensure
    /// phase by budget degradation.
    pub fn set_recompute(&self, on: bool) {
        self.recompute.set(on);
    }

    /// Whether chunked steps currently recompute activations (either
    /// configured or budget-degraded).
    pub fn recompute_active(&self) -> bool {
        self.recompute.get()
    }

    /// Set the activation memory budget in bytes (0 = unlimited; see
    /// `TrainConfig::mem_budget`).
    pub fn set_mem_budget(&self, bytes: usize) {
        self.mem_budget.set(bytes);
    }

    /// The arena's activation high-water mark (bytes) of the most recent
    /// step — each fused step restarts the gauge, so this is per-step
    /// attribution: the peak-bytes audit (`tests/zero_alloc.rs`) and
    /// `benches/longctx.rs` read it to prove recomputed execution is
    /// flat in stream length while cached execution grows.
    pub fn arena_peak_bytes(&self) -> usize {
        self.ws.borrow().arena.peak_bytes()
    }

    /// Drop the persisted cross-batch chunk carry (e.g. between
    /// unrelated evaluation runs).  The next chunked step starts from a
    /// zero stream state.
    pub fn reset_chunk_carry(&self) {
        if let Some(c) = self.chunk_carry.borrow_mut().take() {
            c.release(&mut self.ws.borrow_mut().arena);
        }
    }

    fn note(&self, name: &str, secs: f64) {
        let mut stats = self.stats.borrow_mut();
        // lookup by &str first: the entry API would allocate a key String
        // on every call, breaking the zero-alloc steady state
        if let Some(s) = stats.get_mut(name) {
            s.calls += 1;
            s.exec_secs += secs;
        } else {
            let s = stats.entry(name.to_string()).or_default();
            s.calls = 1;
            s.exec_secs = secs;
        }
    }

    /// Canonical specs for `model`, cached across steps.
    fn cached_specs(&self, model: &ModelConfig) -> Ref<'_, Vec<ParamSpec>> {
        {
            let mut cache = self.specs_cache.borrow_mut();
            let stale = match &*cache {
                Some((m, _)) => m != model,
                None => true,
            };
            if stale {
                *cache = Some((model.clone(), params::specs(model)));
            }
        }
        Ref::map(self.specs_cache.borrow(), |c| &c.as_ref().unwrap().1)
    }

    /// Size the persistent gradient buffers to `specs` (warmup only).
    fn ensure_grad_bufs(&self, specs: &[ParamSpec]) {
        let mut bufs = self.grad_bufs.borrow_mut();
        let fits = bufs.len() == specs.len()
            && bufs
                .iter()
                .zip(specs)
                .all(|(b, s)| b.len() == s.element_count());
        if !fits {
            *bufs = specs
                .iter()
                .map(|s| vec![0.0f32; s.element_count()])
                .collect();
        }
    }

    fn check_batch(&self, model: &ModelConfig, batch: &PackedBatch) -> Result<()> {
        let v = model.vocab_size as i32;
        anyhow::ensure!(
            batch.tokens.data().iter().all(|&t| (0..v).contains(&t)),
            "batch contains tokens outside vocab 0..{v}"
        );
        Ok(())
    }

    /// The batch's validated stream count for chunked execution (rows
    /// must divide evenly into streams; `chunk_len` must be positive) —
    /// the single source of the partition rule for every chunked entry
    /// point.
    fn batch_streams(batch: &PackedBatch, chunk_len: usize) -> Result<usize> {
        anyhow::ensure!(chunk_len > 0, "chunk_len must be positive");
        let streams = batch.streams.max(1);
        anyhow::ensure!(
            batch.rows() % streams == 0,
            "batch of {} rows has a degenerate stream partition ({streams})",
            batch.rows()
        );
        Ok(streams)
    }

    /// Ensure phase shared by the chunked training entry points:
    /// validates the batch's stream partition, sizes the workspace
    /// scratch, sizes the activation working set against the memory
    /// budget (degrading to recomputation or failing fast **before any
    /// chunk executes** — never mid-step), and keeps the persisted
    /// per-stream carry consistent — when the model or the stream count
    /// changed (e.g. the packer's final undersized flush batch
    /// collapsing to fewer streams), the carry is reset to zeros rather
    /// than reinterpreting stale lanes as another stream's state.
    /// `step` drives the `mem.pressure` failpoint (the fused train paths
    /// pass the optimizer step; the dp grads path passes 0).  Returns
    /// the batch's stream count.
    fn ensure_chunked(
        &self,
        model_cfg: &ModelConfig,
        batch: &PackedBatch,
        chunk_len: usize,
        step: u64,
    ) -> Result<usize> {
        let streams = Self::batch_streams(batch, chunk_len)?;
        let mut ws = self.ws.borrow_mut();
        ws.ensure_scratch(batch.rows() * batch.pack_len());
        let stream_tokens = batch.rows() / streams * batch.pack_len();
        ws.ensure_chunk_gather(streams, chunk_len.min(stream_tokens));
        self.size_mem_budget(model_cfg, streams, stream_tokens, chunk_len, step)?;
        let mut carry = self.chunk_carry.borrow_mut();
        let fits = carry.as_ref().is_some_and(|c| c.fits(model_cfg, streams));
        if !fits {
            if let Some(old) = carry.take() {
                log::debug!(
                    "chunked carry reset: model/stream geometry changed \
                     (now {streams} streams)"
                );
                old.release(&mut ws.arena);
            }
            *carry = Some(ws.take_chunk_state(model_cfg, streams, true));
        }
        Ok(streams)
    }

    /// Activation-budget sizing for the chunked step (ensure phase).
    /// Estimates the live activation working set of both execution
    /// modes from the model dims and the chunk geometry:
    ///
    /// * cached — every chunk's forward caches plus its carry-in stay
    ///   live across the whole backward sweep: `n_chunks × (caches +
    ///   state)`;
    /// * recomputed — one chunk's caches live at a time, plus every
    ///   chunk's constant-size carry-in: `caches + n_chunks × state`.
    ///
    /// Over budget in cached mode degrades to recomputation (logged
    /// once, counted via [`trace::count_recompute_switch`]); over
    /// budget even recomputed fails fast with the typed
    /// [`MemBudgetExceeded`] naming the shortfall.  The `mem.pressure`
    /// failpoint (`error` action) injects an over-budget report here,
    /// making both paths deterministically testable.
    fn size_mem_budget(
        &self,
        model_cfg: &ModelConfig,
        streams: usize,
        stream_tokens: usize,
        chunk_len: usize,
        step: u64,
    ) -> Result<()> {
        let budget = self.mem_budget.get();
        let pressured = failpoint::enabled()
            && failpoint::check("mem.pressure", step, 0) == Some(failpoint::Action::Error);
        if budget == 0 && !pressured {
            return Ok(());
        }
        let clen = chunk_len.min(stream_tokens);
        let n_chunks = stream_tokens.div_ceil(chunk_len);
        let caches = model::chunk_cache_bytes(model_cfg, streams, clen);
        let state = model::chunk_state_bytes(model_cfg, streams);
        // both modes also hold the persisted cross-batch carry and the
        // backward's adjoint state: two extra states
        let cached_need = n_chunks * (caches + state) + 2 * state;
        let recompute_need = caches + n_chunks * state + 2 * state;
        let over_cached = pressured || cached_need > budget;
        let over_recompute = (budget > 0 && recompute_need > budget)
            || (pressured && self.recompute.get());
        if over_recompute {
            // fail fast at warmup with the typed shortfall — never
            // mid-step.  A purely injected report (no real budget, or a
            // budget the estimate actually fits) models a budget one
            // byte below the recompute need.
            let named_budget = if budget > 0 && recompute_need > budget {
                budget
            } else {
                recompute_need.saturating_sub(1)
            };
            return Err(anyhow::Error::new(MemBudgetExceeded {
                needed_bytes: recompute_need,
                budget_bytes: named_budget,
            }));
        }
        if over_cached && !self.recompute.get() {
            // graceful degradation: switch this backend to recomputation
            self.recompute.set(true);
            trace::count_recompute_switch();
            if !self.degraded_logged.replace(true) {
                log::warn!(
                    "activation budget: cached chunked execution needs \
                     ~{cached_need} bytes (> budget {budget}); degrading to \
                     recomputation (~{recompute_need} bytes)"
                );
            }
        }
        Ok(())
    }

    /// Deterministic `grads.inject` failpoint: poisons the first
    /// gradient element with NaN when armed for `step`, exercising the
    /// guard path end to end. One relaxed load when disarmed.
    fn maybe_inject_nan(&self, step: usize) {
        if failpoint::enabled()
            && failpoint::check("grads.inject", step as u64, 0) == Some(failpoint::Action::Nan)
        {
            if let Some(g) = self.grad_bufs.borrow_mut().first_mut().and_then(|g| g.first_mut()) {
                *g = f32::NAN;
            }
        }
    }

    /// Non-finite guard for the fused step paths, run **before** AdamW
    /// touches params or moments.  Returns `Ok(true)` when the update
    /// should apply; `Ok(false)` skips it (the step counter still
    /// advances, keeping step accounting deterministic); errors after
    /// `max_bad_steps` *consecutive* bad steps.  Scans existing slices
    /// only — no allocation on either path.
    fn guard_step(&self, loss: f32, grads: &[Vec<f32>], step: usize) -> Result<bool> {
        let _sp = trace::span(Op::GuardScan);
        let finite =
            loss.is_finite() && grads.iter().all(|g| g.iter().all(|x| x.is_finite()));
        if finite {
            self.bad_steps.set(0);
            return Ok(true);
        }
        trace::count_nonfinite_skip();
        let bad = self.bad_steps.get() + 1;
        self.bad_steps.set(bad);
        let max = self.max_bad_steps.get();
        anyhow::ensure!(
            bad < max,
            "aborting after {bad} consecutive non-finite steps \
             (step {step}, loss {loss}); params are unmodified since the \
             last finite step"
        );
        log::warn!(
            "non-finite loss/grads at step {step} (loss {loss}): \
             skipping optimizer update ({bad}/{max} consecutive)"
        );
        Ok(false)
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Native
    }

    fn geometry(&self, cfg: &TrainConfig) -> Result<BatchGeometry> {
        // Native execution handles any geometry; echo the packing config
        // so the trainer's pipeline and the compute agree by definition.
        let rows = cfg.packing.rows;
        let pack_len = cfg.packing.pack_len;
        anyhow::ensure!(rows > 0 && pack_len > 0, "degenerate batch geometry");
        let pad_len = match cfg.scheme {
            Scheme::Padding => cfg.max_len.clamp(1, pack_len),
            _ => pack_len,
        };
        Ok(BatchGeometry {
            rows,
            pack_len,
            buckets: native_buckets(pack_len),
            pad_geom: (rows, pad_len),
        })
    }

    fn init_state(&self, model: &ModelConfig, seed: u64) -> Result<TrainState> {
        let t0 = Instant::now();
        let params = params::init(model, seed);
        let zeros: Vec<Tensor> = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        self.note("init", t0.elapsed().as_secs_f64());
        Ok(TrainState {
            m: zeros.clone(),
            v: zeros,
            params,
            step: 0,
        })
    }

    fn train_step(
        &self,
        model: &ModelConfig,
        state: &mut TrainState,
        batch: &PackedBatch,
    ) -> Result<f32> {
        let _sp = trace::span(Op::TrainStep);
        if trace::enabled() {
            trace::count_tokens(
                batch.real_tokens() as u64,
                (batch.rows() * batch.pack_len()) as u64,
            );
        }
        self.check_batch(model, batch)?;
        let specs = self.cached_specs(model);
        self.ensure_grad_bufs(specs.as_slice());
        self.ws
            .borrow_mut()
            .ensure_scratch(batch.rows() * batch.pack_len());
        let t0 = Instant::now();
        let loss = {
            let mut ws = self.ws.borrow_mut();
            let mut grads = self.grad_bufs.borrow_mut();
            ws.arena.reset_peak();
            let loss = model::loss_and_grads_into(
                model,
                &state.params,
                batch.tokens.data(),
                batch.targets.data(),
                batch.position_indices.data(),
                batch.loss_mask.data(),
                batch.rows(),
                batch.pack_len(),
                self.threads,
                &mut ws,
                &mut grads,
            );
            trace::note_mem_peak(ws.arena.peak_bytes() as u64);
            loss
        };
        let t1 = Instant::now();
        self.maybe_inject_nan(state.step);
        {
            let grads = self.grad_bufs.borrow();
            if self.guard_step(loss, grads.as_slice(), state.step)? {
                adamw::apply_slices(&self.opt, specs.as_slice(), state, grads.as_slice())?;
            }
        }
        state.step += 1;
        let t2 = Instant::now();
        self.note("train_step.fwd_bwd", (t1 - t0).as_secs_f64());
        self.note("train_step.adamw", (t2 - t1).as_secs_f64());
        Ok(loss)
    }

    fn forward(
        &self,
        model: &ModelConfig,
        state_params: &[Tensor],
        batch: &PackedBatch,
    ) -> Result<Tensor> {
        self.check_batch(model, batch)?;
        let t0 = Instant::now();
        let logits = model::forward_logits(
            model,
            state_params,
            batch.tokens.data(),
            batch.position_indices.data(),
            batch.rows(),
            batch.pack_len(),
            self.threads,
            &mut self.ws.borrow_mut(),
        );
        self.note("forward", t0.elapsed().as_secs_f64());
        Ok(logits)
    }

    fn forward_chunked(
        &self,
        model: &ModelConfig,
        state_params: &[Tensor],
        batch: &PackedBatch,
        chunk_len: usize,
    ) -> Result<Tensor> {
        self.check_batch(model, batch)?;
        let streams = Self::batch_streams(batch, chunk_len)?;
        let t0 = Instant::now();
        let logits = model::forward_logits_chunked(
            model,
            state_params,
            batch.tokens.data(),
            batch.position_indices.data(),
            batch.rows(),
            batch.pack_len(),
            streams,
            chunk_len,
            self.threads,
            &mut self.ws.borrow_mut(),
        );
        self.note("forward_chunked", t0.elapsed().as_secs_f64());
        Ok(logits)
    }

    fn train_step_chunked(
        &self,
        model: &ModelConfig,
        state: &mut TrainState,
        batch: &PackedBatch,
        chunk_len: usize,
    ) -> Result<f32> {
        let _sp = trace::span(Op::TrainStep);
        if trace::enabled() {
            trace::count_tokens(
                batch.real_tokens() as u64,
                (batch.rows() * batch.pack_len()) as u64,
            );
        }
        self.check_batch(model, batch)?;
        let specs = self.cached_specs(model);
        self.ensure_grad_bufs(specs.as_slice());
        let streams = self.ensure_chunked(model, batch, chunk_len, state.step as u64)?;
        let denom = ops::mask_denom(batch.loss_mask.data());
        let t0 = Instant::now();
        let loss = {
            let mut ws = self.ws.borrow_mut();
            let mut grads = self.grad_bufs.borrow_mut();
            let mut carry = self.chunk_carry.borrow_mut();
            ws.arena.reset_peak();
            let loss = model::loss_and_grads_chunked_into(
                model,
                &state.params,
                batch.tokens.data(),
                batch.targets.data(),
                batch.position_indices.data(),
                batch.loss_mask.data(),
                batch.rows(),
                batch.pack_len(),
                streams,
                chunk_len,
                self.threads,
                &mut ws,
                &mut grads,
                denom,
                carry.as_mut(),
                self.recompute.get(),
            );
            trace::note_mem_peak(ws.arena.peak_bytes() as u64);
            loss
        };
        let t1 = Instant::now();
        self.maybe_inject_nan(state.step);
        {
            let grads = self.grad_bufs.borrow();
            if self.guard_step(loss, grads.as_slice(), state.step)? {
                adamw::apply_slices(&self.opt, specs.as_slice(), state, grads.as_slice())?;
            }
        }
        state.step += 1;
        let t2 = Instant::now();
        self.note("train_step_chunked.fwd_bwd", (t1 - t0).as_secs_f64());
        self.note("train_step_chunked.adamw", (t2 - t1).as_secs_f64());
        Ok(loss)
    }

    fn loss_and_grads_chunked(
        &self,
        model: &ModelConfig,
        state_params: &[Tensor],
        batch: &PackedBatch,
        chunk_len: usize,
        denom: f32,
    ) -> Result<(f32, Vec<Tensor>)> {
        self.check_batch(model, batch)?;
        anyhow::ensure!(denom > 0.0, "cross-entropy denom must be positive");
        let specs = self.cached_specs(model);
        // the dp grads path has no optimizer-step context; the
        // mem.pressure failpoint matches it at step 0 (or stepless rules)
        let streams = self.ensure_chunked(model, batch, chunk_len, 0)?;
        let t0 = Instant::now();
        // fresh grad buffers (they are moved into the returned tensors);
        // activations and chunk spines still reuse the persistent arena
        let mut grads: Vec<Vec<f32>> = specs
            .iter()
            .map(|s| vec![0.0f32; s.element_count()])
            .collect();
        let loss = {
            let mut ws = self.ws.borrow_mut();
            let mut carry = self.chunk_carry.borrow_mut();
            ws.arena.reset_peak();
            let loss = model::loss_and_grads_chunked_into(
                model,
                state_params,
                batch.tokens.data(),
                batch.targets.data(),
                batch.position_indices.data(),
                batch.loss_mask.data(),
                batch.rows(),
                batch.pack_len(),
                streams,
                chunk_len,
                self.threads,
                &mut ws,
                &mut grads,
                denom,
                carry.as_mut(),
                self.recompute.get(),
            );
            trace::note_mem_peak(ws.arena.peak_bytes() as u64);
            loss
        };
        self.note("grads_chunked", t0.elapsed().as_secs_f64());
        // no finite check here: in data-parallel training the *leader*
        // guards the reduced loss/grads and directs a coordinated skip
        let tensors = specs
            .iter()
            .zip(grads)
            .map(|(s, g)| Tensor::new(&s.shape, g))
            .collect();
        Ok((loss, tensors))
    }

    fn loss_and_grads(
        &self,
        model: &ModelConfig,
        state_params: &[Tensor],
        batch: &PackedBatch,
    ) -> Result<(f32, Vec<Tensor>)> {
        self.check_batch(model, batch)?;
        let specs = self.cached_specs(model);
        self.ws
            .borrow_mut()
            .ensure_scratch(batch.rows() * batch.pack_len());
        let t0 = Instant::now();
        // fresh grad buffers (they are moved into the returned tensors);
        // activations still reuse the persistent arena
        let mut grads: Vec<Vec<f32>> = specs
            .iter()
            .map(|s| vec![0.0f32; s.element_count()])
            .collect();
        let loss = model::loss_and_grads_into(
            model,
            state_params,
            batch.tokens.data(),
            batch.targets.data(),
            batch.position_indices.data(),
            batch.loss_mask.data(),
            batch.rows(),
            batch.pack_len(),
            self.threads,
            &mut self.ws.borrow_mut(),
            &mut grads,
        );
        self.note("grads", t0.elapsed().as_secs_f64());
        // no finite check here: in data-parallel training the *leader*
        // guards the reduced loss/grads and directs a coordinated skip
        let tensors = specs
            .iter()
            .zip(grads)
            .map(|(s, g)| Tensor::new(&s.shape, g))
            .collect();
        Ok((loss, tensors))
    }

    fn apply_update(
        &self,
        model: &ModelConfig,
        state: &mut TrainState,
        grads: &[Tensor],
    ) -> Result<()> {
        let t0 = Instant::now();
        adamw::apply(&self.opt, self.cached_specs(model).as_slice(), state, grads)?;
        state.step += 1;
        self.note("adam_apply", t0.elapsed().as_secs_f64());
        Ok(())
    }

    fn export_chunk_carry(&self, model: &ModelConfig) -> Option<CarryState> {
        let carry = self.chunk_carry.borrow();
        let c = carry.as_ref()?;
        let per_lane = model.d_inner() * model.d_state;
        let h0 = c.h.first()?;
        if per_lane == 0 || h0.len() % per_lane != 0 {
            return None; // carry does not match this model's shape
        }
        let lanes = h0.len() / per_lane;
        if !c.fits(model, lanes) {
            return None;
        }
        Some(CarryState {
            lanes,
            h: c.h.clone(),
            tail: c.tail.clone(),
        })
    }

    fn import_chunk_carry(&self, model: &ModelConfig, carry: &CarryState) -> Result<()> {
        let (di, n, wl) = (model.d_inner(), model.d_state, model.d_conv);
        anyhow::ensure!(
            carry.lanes > 0
                && carry.h.len() == model.n_layers
                && carry.tail.len() == model.n_layers
                && carry.h.iter().all(|v| v.len() == carry.lanes * di * n)
                && carry.tail.iter().all(|v| v.len() == carry.lanes * di * (wl - 1)),
            "chunk carry shape does not match model `{}` ({} lanes)",
            model.name,
            carry.lanes
        );
        let mut ws = self.ws.borrow_mut();
        let mut slot = self.chunk_carry.borrow_mut();
        if let Some(old) = slot.take() {
            old.release(&mut ws.arena);
        }
        let mut cs = ws.take_chunk_state(model, carry.lanes, false);
        for (dst, src) in cs.h.iter_mut().zip(&carry.h) {
            dst.copy_from_slice(src);
        }
        for (dst, src) in cs.tail.iter_mut().zip(&carry.tail) {
            dst.copy_from_slice(src);
        }
        *slot = Some(cs);
        Ok(())
    }

    fn param_specs(&self, model: &ModelConfig) -> Result<Vec<ParamSpec>> {
        Ok(params::specs(model))
    }

    fn stats(&self) -> Vec<(String, ExecStats)> {
        let mut out: Vec<(String, ExecStats)> = self
            .stats
            .borrow()
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::{PackedRow, Sequence};

    fn nano() -> ModelConfig {
        ModelConfig {
            name: "nano".to_string(),
            vocab_size: 31,
            d_model: 16,
            n_layers: 2,
            d_state: 4,
            d_conv: 4,
            expand: 2,
        }
    }

    fn batch(pack_len: usize) -> PackedBatch {
        let seq = |id: u64, n: usize| Sequence {
            tokens: (0..n).map(|k| 1 + ((id as usize * 7 + k * 3) % 30) as i32).collect(),
            id,
        };
        PackedBatch::from_rows(
            &[
                PackedRow {
                    sequences: vec![seq(0, 9), seq(1, 5)],
                },
                PackedRow {
                    sequences: vec![seq(2, 12)],
                },
            ],
            pack_len,
        )
    }

    #[test]
    fn fused_step_equals_grads_plus_apply() {
        let cfg = nano();
        let be = NativeBackend::with_threads(2);
        let mut s1 = be.init_state(&cfg, 11).unwrap();
        let mut s2 = s1.clone();
        let b = batch(16);

        let l1 = be.train_step(&cfg, &mut s1, &b).unwrap();
        let (l2, grads) = be.loss_and_grads(&cfg, &s2.params, &b).unwrap();
        be.apply_update(&cfg, &mut s2, &grads).unwrap();

        assert_eq!(l1, l2);
        assert_eq!(s1.step, s2.step);
        for (a, bb) in s1.params.iter().zip(&s2.params) {
            assert_eq!(a.data(), bb.data());
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let cfg = nano();
        let b = batch(16);
        let run = |threads: usize| {
            let be = NativeBackend::with_threads(threads);
            let mut st = be.init_state(&cfg, 3).unwrap();
            let mut losses = Vec::new();
            for _ in 0..3 {
                losses.push(be.train_step(&cfg, &mut st, &b).unwrap());
            }
            (losses, st.params)
        };
        let (la, pa) = run(1);
        let (lb, pb) = run(7);
        assert_eq!(la, lb);
        for (x, y) in pa.iter().zip(&pb) {
            assert_eq!(x.data(), y.data());
        }
    }

    #[test]
    fn warm_workspace_does_not_change_results() {
        // A backend whose arena is already warm (from steps on another
        // batch) must produce exactly the numbers a cold backend does.
        let cfg = nano();
        let warm = NativeBackend::with_threads(2);
        let mut throwaway = warm.init_state(&cfg, 5).unwrap();
        for _ in 0..2 {
            warm.train_step(&cfg, &mut throwaway, &batch(32)).unwrap();
        }
        let cold = NativeBackend::with_threads(2);
        let mut sw = warm.init_state(&cfg, 8).unwrap();
        let mut sc = cold.init_state(&cfg, 8).unwrap();
        let b = batch(16);
        for _ in 0..3 {
            let lw = warm.train_step(&cfg, &mut sw, &b).unwrap();
            let lc = cold.train_step(&cfg, &mut sc, &b).unwrap();
            assert_eq!(lw, lc);
        }
        for (x, y) in sw.params.iter().zip(&sc.params) {
            assert_eq!(x.data(), y.data());
        }
    }

    #[test]
    fn rejects_out_of_vocab_tokens() {
        let cfg = nano();
        let be = NativeBackend::with_threads(1);
        let state = be.init_state(&cfg, 1).unwrap();
        let b = PackedBatch::from_rows(
            &[PackedRow {
                sequences: vec![Sequence {
                    tokens: vec![1, 2, 10_000],
                    id: 0,
                }],
            }],
            8,
        );
        assert!(be.forward(&cfg, &state.params, &b).is_err());
    }

    #[test]
    fn geometry_echoes_config_and_buckets_cover() {
        let cfg = TrainConfig::defaults(ModelConfig::tiny());
        let be = NativeBackend::with_threads(1);
        let g = be.geometry(&cfg).unwrap();
        assert_eq!(g.rows, cfg.packing.rows);
        assert_eq!(g.pack_len, cfg.packing.pack_len);
        assert_eq!(*g.buckets.last().unwrap(), cfg.packing.pack_len);
        assert!(g.pad_geom.1 <= g.pack_len);
    }

    #[test]
    fn stats_accumulate_per_op() {
        let cfg = nano();
        let be = NativeBackend::with_threads(1);
        let mut st = be.init_state(&cfg, 2).unwrap();
        be.train_step(&cfg, &mut st, &batch(16)).unwrap();
        be.train_step(&cfg, &mut st, &batch(16)).unwrap();
        let stats = be.stats();
        let names: Vec<&str> = stats.iter().map(|(n, _)| n.as_str()).collect();
        assert!(names.contains(&"train_step.fwd_bwd"), "{names:?}");
        assert!(names.contains(&"train_step.adamw"));
        let fwd = stats
            .iter()
            .find(|(n, _)| n == "train_step.fwd_bwd")
            .unwrap();
        assert_eq!(fwd.1.calls, 2, "note() must accumulate across steps");
    }
}
