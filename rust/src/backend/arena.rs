//! `StepArena` — bump-style reusable scratch for the native training
//! step.
//!
//! Every buffer the forward/backward needs (activations, caches,
//! gradients' temporaries, GEMM packing scratch) is taken from the arena
//! and returned when dead.  Buffers are recycled **by length**: the first
//! step populates the free lists (warmup), and because a training run
//! replays the same batch geometry every step, every subsequent
//! `take`/`put` hits an existing buffer — the steady-state step performs
//! **zero heap allocations** (asserted by `tests/zero_alloc.rs` with a
//! counting global allocator, single-threaded; with worker threads the
//! scoped spawns themselves are the only remaining allocations).
//!
//! Retained memory is bounded by one step's peak working set — the same
//! high-water mark a non-recycling step reaches mid-backward.

use std::collections::HashMap;

use super::gemm::GemmScratch;

/// Reusable per-backend scratch arena.
#[derive(Default)]
pub struct StepArena {
    /// Free lists keyed by buffer length.
    free: HashMap<usize, Vec<Vec<f32>>>,
    /// GEMM packing scratch (grows to the largest shape seen).
    pub gemm: GemmScratch,
    /// f64 partials for the cross-entropy chunk reduction.
    pub f64_scratch: Vec<f64>,
    taken: usize,
    recycled: usize,
    /// Bytes of arena buffers currently checked out (taken, not put back).
    live_bytes: usize,
    /// High-water mark of `live_bytes` since construction/[`reset_peak`](Self::reset_peak).
    peak_bytes: usize,
}

impl StepArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// A buffer of exactly `len` elements with **unspecified contents**
    /// (stale values from a previous user).  Callers must overwrite every
    /// element; use [`take_zeroed`](Self::take_zeroed) to accumulate.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        self.taken += 1;
        self.live_bytes += len * std::mem::size_of::<f32>();
        if self.live_bytes > self.peak_bytes {
            self.peak_bytes = self.live_bytes;
        }
        if let Some(list) = self.free.get_mut(&len) {
            if let Some(v) = list.pop() {
                self.recycled += 1;
                debug_assert_eq!(v.len(), len);
                return v;
            }
        }
        vec![0.0; len]
    }

    /// A buffer of `len` zeros.
    pub fn take_zeroed(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.take(len);
        v.iter_mut().for_each(|x| *x = 0.0);
        v
    }

    /// Return a buffer to the arena for reuse.
    pub fn put(&mut self, v: Vec<f32>) {
        // saturating: a buffer built outside the arena (capacity > 0 but
        // never `take`n) must not underflow the live-byte gauge
        self.live_bytes = self
            .live_bytes
            .saturating_sub(v.len() * std::mem::size_of::<f32>());
        if v.capacity() == 0 {
            return;
        }
        self.free.entry(v.len()).or_default().push(v);
    }

    /// Return a collection of buffers (e.g. a `ChunkState`'s per-layer
    /// carries) to the arena.
    pub fn put_all(&mut self, vs: impl IntoIterator<Item = Vec<f32>>) {
        for v in vs {
            self.put(v);
        }
    }

    /// `(takes, recycle_hits)` since construction — warmup diagnostics.
    pub fn stats(&self) -> (usize, usize) {
        (self.taken, self.recycled)
    }

    /// Total f32 elements currently parked in free lists.
    pub fn retained_elements(&self) -> usize {
        self.free.values().flatten().map(Vec::len).sum()
    }

    /// Bytes of arena buffers currently checked out.  Mid-backward this
    /// *is* the live activation set: layer caches and carry states are
    /// all arena-backed, so cached chunked execution shows `O(stream
    /// length)` here while recomputed execution stays `O(chunk_len)`.
    pub fn live_bytes(&self) -> usize {
        self.live_bytes
    }

    /// High-water mark of [`live_bytes`](Self::live_bytes) since
    /// construction or the last [`reset_peak`](Self::reset_peak).
    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    /// Restart the peak gauge from the current live level (per-step
    /// attribution: backends call this at the top of a step so the peak
    /// reflects *this* step's working set).
    pub fn reset_peak(&mut self) {
        self.peak_bytes = self.live_bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycles_by_length() {
        let mut a = StepArena::new();
        let v = a.take(16);
        let p = v.as_ptr();
        a.put(v);
        let v2 = a.take(16);
        assert_eq!(v2.as_ptr(), p, "same buffer must come back");
        assert_eq!(v2.len(), 16);
        let (takes, hits) = a.stats();
        assert_eq!((takes, hits), (2, 1));
    }

    #[test]
    fn take_zeroed_clears_stale_contents() {
        let mut a = StepArena::new();
        let mut v = a.take(8);
        v.iter_mut().for_each(|x| *x = 7.0);
        a.put(v);
        assert!(a.take_zeroed(8).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn distinct_lengths_do_not_cross() {
        let mut a = StepArena::new();
        let v = a.take(8);
        a.put(v);
        let w = a.take(9);
        assert_eq!(w.len(), 9);
        assert_eq!(a.retained_elements(), 8);
    }

    #[test]
    fn live_and_peak_bytes_track_checkouts() {
        let mut a = StepArena::new();
        let sz = std::mem::size_of::<f32>();
        let v = a.take(8);
        let w = a.take(4);
        assert_eq!(a.live_bytes(), 12 * sz);
        assert_eq!(a.peak_bytes(), 12 * sz);
        a.put(w);
        assert_eq!(a.live_bytes(), 8 * sz, "put must release live bytes");
        assert_eq!(a.peak_bytes(), 12 * sz, "peak is a high-water mark");
        a.reset_peak();
        assert_eq!(a.peak_bytes(), 8 * sz, "reset restarts from live");
        let x = a.take(4); // recycled buffer still counts as live
        assert_eq!(a.live_bytes(), 12 * sz);
        assert_eq!(a.peak_bytes(), 12 * sz);
        a.put(x);
        a.put(v);
        assert_eq!(a.live_bytes(), 0);
    }

    #[test]
    fn foreign_put_saturates_instead_of_underflowing() {
        let mut a = StepArena::new();
        a.put(vec![0.0; 16]); // never taken from this arena
        assert_eq!(a.live_bytes(), 0);
        let v = a.take(16);
        a.put(v);
        assert_eq!(a.live_bytes(), 0);
    }
}
