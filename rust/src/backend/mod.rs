//! Execution backends: the trainer's pluggable compute layer.
//!
//! The coordinator talks to a [`Backend`] trait instead of any concrete
//! runtime.  Two implementations exist:
//!
//! * [`NativeBackend`] (default) — a pure-Rust, multi-threaded CPU
//!   implementation of the packed Mamba training step: embedding,
//!   RMSNorm, the gated Mamba block with **packed causal conv1d** and
//!   **packed selective scan** (the paper's §3 operator modifications,
//!   in [`kernels`]), masked cross-entropy, full analytic backward, and
//!   fused AdamW.  The GEMM-shaped projections run on the blocked,
//!   register-tiled micro-kernel in [`gemm`]; per-step buffers are
//!   recycled through the [`arena`] so steady-state steps allocate
//!   nothing.  No artifacts, no external deps: `cargo run` trains out of
//!   the box on any machine.
//! * `PjrtBackend` (`--features pjrt`) — the original AOT-artifact path:
//!   HLO text compiled once on a PJRT CPU client and executed per step.
//!
//! Both expose the same surface — geometry resolution, state init, the
//! fused train step, `loss+grads`/`apply` halves for data-parallel
//! training, forward logits for the PUI tests, and per-op timing stats —
//! so `Trainer`, `DataParallelTrainer`, and the benches are
//! backend-agnostic.  The native backend additionally implements the
//! paper's §5 **chunked/stateful execution**
//! ([`Backend::forward_chunked`] / [`Backend::train_step_chunked`] /
//! [`Backend::loss_and_grads_chunked`]): fixed `L = chunk_len` operator
//! shapes with SSM state + conv tails carried across chunk and row
//! boundaries, enabling sequences longer than `pack_len` (split by the
//! streaming packer) to train without padding blow-up.  A batch's rows
//! may be partitioned into independent **streams**
//! (`PackedBatch::streams`, one carry lane each), which is what lets the
//! chunked step compose with data parallelism: each dp worker owns a
//! stable row range of whole streams and threads its carries alone.

pub mod adamw;
pub mod arena;
pub mod gemm;
pub mod kernels;
pub mod model;
pub mod native;
pub mod ops;
pub mod params;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use native::{MemBudgetExceeded, NativeBackend};

use crate::config::{BackendKind, ModelConfig, TrainConfig};
use crate::packing::PackedBatch;
use crate::runtime::{ExecStats, ParamSpec};
use crate::tensor::Tensor;
use crate::Result;

/// Model + optimizer state as flat host tensors (canonical parameter
/// order; see [`params`]).
#[derive(Clone, Debug)]
pub struct TrainState {
    pub params: Vec<Tensor>,
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
    pub step: usize,
}

impl TrainState {
    pub fn param_count(&self) -> usize {
        self.params.iter().map(Tensor::len).sum()
    }
}

/// Owned copy of a backend's persisted per-stream chunk carry (§5):
/// per-layer SSM state lanes `(lanes, d_inner, d_state)` and conv tails
/// `(lanes, d_inner, d_conv - 1)`, lane-major.  Part of the full resume
/// state — a chunked run restarted without it silently recomputes from
/// zeroed carries and diverges from the uninterrupted run.
#[derive(Clone, Debug, PartialEq)]
pub struct CarryState {
    /// carry lanes (= streams of the batches this backend has stepped)
    pub lanes: usize,
    /// per-layer SSM state, `lanes * d_inner * d_state` each
    pub h: Vec<Vec<f32>>,
    /// per-layer conv tails, `lanes * d_inner * (d_conv - 1)` each
    pub tail: Vec<Vec<f32>>,
}

/// Batch geometry a backend can execute for a given config + scheme.
///
/// The native backend echoes the packing config (any geometry runs); the
/// PJRT backend reports the fixed geometry its compiled artifacts were
/// built for, which the trainer then imposes on the data pipeline.
#[derive(Clone, Debug)]
pub struct BatchGeometry {
    /// rows per packed batch
    pub rows: usize,
    /// slots per row
    pub pack_len: usize,
    /// single-sequence bucket lengths, ascending
    pub buckets: Vec<usize>,
    /// (rows, max_len) for the padding scheme
    pub pad_geom: (usize, usize),
}

/// A training compute backend.
///
/// Contract: [`Backend::geometry`] is called once per trainer before any
/// step — the PJRT backend uses it to resolve and cache the scheme's
/// step executables.
pub trait Backend {
    /// Which backend this is (for logs and config round-trips).
    fn kind(&self) -> BackendKind;

    /// Resolve the batch geometry for `cfg.scheme`.
    fn geometry(&self, cfg: &TrainConfig) -> Result<BatchGeometry>;

    /// Fresh model + optimizer state.
    fn init_state(&self, model: &ModelConfig, seed: u64) -> Result<TrainState>;

    /// Fused train step (forward, backward, AdamW): updates `state` in
    /// place and returns the loss.
    fn train_step(
        &self,
        model: &ModelConfig,
        state: &mut TrainState,
        batch: &PackedBatch,
    ) -> Result<f32>;

    /// Forward logits `(rows, pack_len, vocab)` — the PUI surface.
    fn forward(
        &self,
        model: &ModelConfig,
        state_params: &[Tensor],
        batch: &PackedBatch,
    ) -> Result<Tensor>;

    /// Chunked/stateful forward (paper §5): the batch's rows are
    /// traversed as one row-major stream in `chunk_len`-slot pieces,
    /// carrying SSM state + conv tails across chunk *and row* boundaries
    /// (so sequences split over consecutive rows by the streaming packer
    /// execute exactly); `pos == 0` still isolates every fresh start.
    /// Stateless across calls; equals [`Backend::forward`] within fp
    /// reassociation.  Backends without chunked support return an error.
    fn forward_chunked(
        &self,
        model: &ModelConfig,
        state_params: &[Tensor],
        batch: &PackedBatch,
        chunk_len: usize,
    ) -> Result<Tensor> {
        let _ = (model, state_params, batch, chunk_len);
        anyhow::bail!(
            "backend `{}` does not support chunked execution",
            self.kind().name()
        )
    }

    /// Fused chunked train step (paper §5): forward/backward in
    /// `chunk_len` pieces with full BPTT across the stream's chunks,
    /// then AdamW.  The stream-end carry state persists into the next
    /// call (truncated BPTT across batches), so sequences the packer
    /// split across batch boundaries continue with real state; fresh
    /// `pos == 0` starts discard it automatically.
    fn train_step_chunked(
        &self,
        model: &ModelConfig,
        state: &mut TrainState,
        batch: &PackedBatch,
        chunk_len: usize,
    ) -> Result<f32> {
        let _ = (model, state, batch, chunk_len);
        anyhow::bail!(
            "backend `{}` does not support chunked execution",
            self.kind().name()
        )
    }

    /// `(loss, grads)` — the worker half of data-parallel training.
    fn loss_and_grads(
        &self,
        model: &ModelConfig,
        state_params: &[Tensor],
        batch: &PackedBatch,
    ) -> Result<(f32, Vec<Tensor>)>;

    /// `(loss, grads)` of the chunked/stateful step (§5) — the worker
    /// half of **chunk-aware data-parallel training** (§4).  `batch` is
    /// this worker's stable row range of the step's batch (a contiguous
    /// run of whole streams, [`PackedBatch::split_rows`]); the worker's
    /// per-stream carry persists across calls, exactly as in
    /// [`Backend::train_step_chunked`].  `denom` is the cross-entropy
    /// normalizer of the *whole* (unsplit) batch, so the returned loss
    /// and gradients are partial contributions: **summing** them across
    /// workers reproduces the single-worker chunked step's loss and
    /// gradients.  Backends without chunked support return an error.
    fn loss_and_grads_chunked(
        &self,
        model: &ModelConfig,
        state_params: &[Tensor],
        batch: &PackedBatch,
        chunk_len: usize,
        denom: f32,
    ) -> Result<(f32, Vec<Tensor>)> {
        let _ = (model, state_params, batch, chunk_len, denom);
        anyhow::bail!(
            "backend `{}` does not support chunked execution",
            self.kind().name()
        )
    }

    /// Apply one optimizer update with externally averaged grads — the
    /// leader half of data-parallel training.
    fn apply_update(
        &self,
        model: &ModelConfig,
        state: &mut TrainState,
        grads: &[Tensor],
    ) -> Result<()>;

    /// Canonical parameter layout (checkpoint header).
    fn param_specs(&self, model: &ModelConfig) -> Result<Vec<ParamSpec>>;

    /// Cumulative per-op timing, sorted by name.
    fn stats(&self) -> Vec<(String, ExecStats)>;

    /// Owned copy of the persisted chunk carry for checkpointing
    /// (`None` when no chunked step has run or the carry was reset).
    /// Backends without chunked support have nothing to export.
    fn export_chunk_carry(&self, model: &ModelConfig) -> Option<CarryState> {
        let _ = model;
        None
    }

    /// Restore a carry exported by [`Backend::export_chunk_carry`]; the
    /// next chunked step continues from it bit-exactly.
    fn import_chunk_carry(&self, model: &ModelConfig, carry: &CarryState) -> Result<()> {
        let _ = (model, carry);
        anyhow::bail!(
            "backend `{}` does not support chunk-carry restore",
            self.kind().name()
        )
    }
}

/// Construct the backend selected by `cfg.backend`.
///
/// Each data-parallel worker calls this on its own thread: backends are
/// deliberately not `Send` (the PJRT client is thread-local), mirroring
/// the one-process-per-device layout of the paper's 8-GPU setup.
pub fn create(cfg: &TrainConfig) -> Result<Box<dyn Backend>> {
    match cfg.backend {
        BackendKind::Native => {
            let be = NativeBackend::new();
            be.set_max_bad_steps(cfg.max_bad_steps);
            be.set_recompute(cfg.recompute);
            be.set_mem_budget(cfg.mem_budget);
            Ok(Box::new(be))
        }
        BackendKind::Pjrt => create_pjrt(cfg),
    }
}

#[cfg(feature = "pjrt")]
fn create_pjrt(cfg: &TrainConfig) -> Result<Box<dyn Backend>> {
    Ok(Box::new(pjrt::PjrtBackend::load(std::path::Path::new(
        &cfg.artifacts_dir,
    ))?))
}

#[cfg(not(feature = "pjrt"))]
fn create_pjrt(_cfg: &TrainConfig) -> Result<Box<dyn Backend>> {
    anyhow::bail!(
        "backend `pjrt` requires building with `--features pjrt` \
         (and a real xla crate patched in; see vendor/xla)"
    )
}

/// Single-sequence bucket lengths for a native run: powers of two from 16
/// up to (and always including) `pack_len`.
pub(crate) fn native_buckets(pack_len: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut b = 16usize.min(pack_len.max(1));
    while b < pack_len {
        out.push(b);
        b *= 2;
    }
    out.push(pack_len);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_buckets_cover_pack_len() {
        assert_eq!(native_buckets(256), vec![16, 32, 64, 128, 256]);
        assert_eq!(native_buckets(96), vec![16, 32, 64, 96]);
        assert_eq!(native_buckets(16), vec![16]);
        assert_eq!(native_buckets(8), vec![8]);
    }

    #[test]
    fn factory_honours_config_kind() {
        let cfg = TrainConfig::defaults(crate::config::ModelConfig::tiny());
        let b = create(&cfg).unwrap();
        assert_eq!(b.kind(), BackendKind::Native);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_without_feature_is_a_clear_error() {
        let mut cfg = TrainConfig::defaults(crate::config::ModelConfig::tiny());
        cfg.backend = BackendKind::Pjrt;
        let err = create(&cfg).unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }
}
