//! Offline shim for the `anyhow` API subset this workspace uses.
//!
//! The build environment has no crates.io access, so this tiny crate
//! provides source-compatible `anyhow::{Result, Error, anyhow!, ensure!,
//! bail!}`.  An [`Error`] is either a message string or a boxed typed
//! root error plus a stack of context strings: `?` works on any
//! `std::error::Error` via the blanket `From` impl (which is coherent
//! because `Error` itself deliberately does not implement
//! `std::error::Error`, mirroring the real crate's design), and a typed
//! root stays downcastable through any number of [`Error::context`]
//! frames — the fault-tolerance suite pulls `WorkerError` back out of a
//! contextualized dp failure this way.

use std::fmt;

enum Root {
    Msg(String),
    Boxed(Box<dyn std::error::Error + Send + Sync + 'static>),
}

/// Message- or typed-root-backed error value with context frames.
pub struct Error {
    /// Context frames, outermost first; `{e}` shows the outermost
    /// frame (or the root), `{e:#}` joins the whole chain with `: `
    /// like the real crate's alternate mode.
    ctx: Vec<String>,
    root: Root,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            ctx: Vec::new(),
            root: Root::Msg(message.to_string()),
        }
    }

    /// Construct from a typed error, preserving it for
    /// [`downcast_ref`](Error::downcast_ref).
    pub fn new<E: std::error::Error + Send + Sync + 'static>(e: E) -> Error {
        Error {
            ctx: Vec::new(),
            root: Root::Boxed(Box::new(e)),
        }
    }

    /// Wrap with an outer context frame (the real crate's
    /// `Context::context` on an already-built `Error`).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.ctx.insert(0, context.to_string());
        self
    }

    /// Downcast the *root* error; context frames are transparent, as in
    /// the real crate.
    pub fn downcast_ref<T: std::error::Error + 'static>(&self) -> Option<&T> {
        match &self.root {
            Root::Boxed(e) => e.as_ref().downcast_ref::<T>(),
            Root::Msg(_) => None,
        }
    }

    fn fmt_root(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.root {
            Root::Msg(m) => f.write_str(m),
            Root::Boxed(e) => write!(f, "{e}"),
        }
    }

    fn fmt_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for c in &self.ctx {
            write!(f, "{c}: ")?;
        }
        self.fmt_root(f)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{e:#}`: the full chain, outermost context first.
            self.fmt_chain(f)
        } else {
            match self.ctx.first() {
                Some(c) => f.write_str(c),
                None => self.fmt_root(f),
            }
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `unwrap()`-style output: show the whole chain.
        self.fmt_chain(f)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Build an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn macros_format() {
        fn fails(x: usize) -> Result<()> {
            ensure!(x > 2, "x too small: {x}");
            if x > 100 {
                bail!("x too big: {}", x);
            }
            Ok(())
        }
        assert!(fails(5).is_ok());
        assert_eq!(fails(1).unwrap_err().to_string(), "x too small: 1");
        assert_eq!(fails(101).unwrap_err().to_string(), "x too big: 101");
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        assert_eq!(format!("{e:#}"), "plain");
    }

    #[test]
    fn ensure_without_message() {
        fn f() -> Result<()> {
            ensure!(1 + 1 == 3);
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("condition failed"));
    }

    #[test]
    fn typed_root_survives_context_frames() {
        let e = Error::new(io_err())
            .context("reading checkpoint")
            .context("step 7 failed");
        assert_eq!(format!("{e}"), "step 7 failed");
        assert_eq!(
            format!("{e:#}"),
            "step 7 failed: reading checkpoint: disk on fire"
        );
        let io = e.downcast_ref::<std::io::Error>().expect("typed root");
        assert_eq!(io.to_string(), "disk on fire");
        assert!(e.downcast_ref::<std::fmt::Error>().is_none());
    }

    #[test]
    fn message_roots_do_not_downcast() {
        let e = anyhow!("plain").context("outer");
        assert!(e.downcast_ref::<std::io::Error>().is_none());
        assert_eq!(format!("{e:#}"), "outer: plain");
    }

    #[test]
    fn question_mark_keeps_the_typed_root() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.downcast_ref::<std::io::Error>().is_some());
    }
}
