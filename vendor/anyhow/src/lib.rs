//! Offline shim for the `anyhow` API subset this workspace uses.
//!
//! The build environment has no crates.io access, so this tiny crate
//! provides source-compatible `anyhow::{Result, Error, anyhow!, ensure!,
//! bail!}`.  Errors are a message string; `?` works on any
//! `std::error::Error` via the blanket `From` impl (which is coherent
//! because `Error` itself deliberately does not implement
//! `std::error::Error`, mirroring the real crate's design).

use std::fmt;

/// String-backed error value.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{e}` and `{e:#}` both print the message; the shim keeps no
        // cause chain to elaborate in alternate mode.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Build an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn macros_format() {
        fn fails(x: usize) -> Result<()> {
            ensure!(x > 2, "x too small: {x}");
            if x > 100 {
                bail!("x too big: {}", x);
            }
            Ok(())
        }
        assert!(fails(5).is_ok());
        assert_eq!(fails(1).unwrap_err().to_string(), "x too small: 1");
        assert_eq!(fails(101).unwrap_err().to_string(), "x too big: 101");
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        assert_eq!(format!("{e:#}"), "plain");
    }

    #[test]
    fn ensure_without_message() {
        fn f() -> Result<()> {
            ensure!(1 + 1 == 3);
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("condition failed"));
    }
}
