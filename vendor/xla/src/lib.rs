//! Compile-only stub of the `xla` (PJRT C API) bindings.
//!
//! The real crate links the PJRT CPU plugin and cannot be vendored in an
//! offline image, so this stub keeps `--features pjrt` *compiling*
//! everywhere: every entry point that would touch PJRT returns a clear
//! runtime error, and the rest are inert value types.  To run the PJRT
//! path for real, point Cargo at an actual `xla` build, e.g. in the
//! workspace root:
//!
//! ```toml
//! [patch."*"]
//! # xla = { path = "/opt/xla-rs" }
//! ```
//!
//! The API surface mirrors exactly what `packmamba::runtime` calls.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type returned by every stubbed PJRT entry point.
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: PJRT is unavailable (this build links the compile-only \
             `xla` stub; patch in a real xla crate to execute artifacts)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes the runtime stages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Bf16,
}

/// Marker type for bf16 raw-buffer copies (zero-sized, as in the real
/// bindings' calling convention the runtime relies on).
#[derive(Clone, Copy, Debug)]
pub struct Bf16;

impl Bf16 {
    pub const ELEMENT_SIZE_IN_BYTES: usize = 2;
}

/// Conversions supported by `Literal::{scalar, vec1, to_vec}`.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host literal (inert in the stub).
pub struct Literal;

impl Literal {
    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal
    }

    pub fn vec1<T: NativeType>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn copy_raw_to<T>(&self, _dst: &mut [T]) -> Result<()> {
        Err(Error::unavailable("Literal::copy_raw_to"))
    }
}

/// Parsed HLO module (inert).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let _ = path.as_ref();
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// Computation wrapper (inert).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (inert).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle (inert).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle; construction fails in the stub.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}
