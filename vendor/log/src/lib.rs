//! Offline shim for the `log` facade subset this workspace uses:
//! levels, the `Log` trait, `set_logger`/`set_max_level`, and the five
//! level macros.  Source-compatible with `util::logging`'s usage of the
//! real crate.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of a single message.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

/// Maximum verbosity a logger accepts (`Off` disables everything).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.pad(s)
    }
}

/// Message metadata (level + target module).
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log message.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    module_path: Option<&'a str>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    /// Module that emitted the record (the macros always populate this
    /// from `module_path!()`; hand-built records may leave it out).
    pub fn module_path(&self) -> Option<&'a str> {
        self.module_path
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// Sink for log records.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);

/// Returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing — not part of the public API.
#[doc(hidden)]
pub fn __private_log<'a>(
    level: Level,
    target: &'a str,
    module_path: Option<&'a str>,
    args: fmt::Arguments<'a>,
) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record {
            metadata: Metadata { level, target },
            module_path,
            args,
        };
        if logger.enabled(record.metadata()) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_log(
            $lvl,
            module_path!(),
            Some(module_path!()),
            format_args!($($arg)+),
        )
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;

    impl Log for Counter {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= LevelFilter::Info
        }

        fn log(&self, record: &Record) {
            // the macros pass module_path!() for both target and module path
            assert_eq!(record.module_path(), Some(record.target()));
            let _ = format!("{} {}", record.target(), record.args());
            HITS.fetch_add(1, Ordering::SeqCst);
        }

        fn flush(&self) {}
    }

    #[test]
    fn levels_compare_to_filters() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Trace > LevelFilter::Off);
    }

    #[test]
    fn macros_route_through_installed_logger() {
        static COUNTER: Counter = Counter;
        let _ = set_logger(&COUNTER);
        set_max_level(LevelFilter::Info);
        let before = HITS.load(Ordering::SeqCst);
        info!("hello {}", 42);
        debug!("filtered out at max level info");
        assert_eq!(HITS.load(Ordering::SeqCst), before + 1);
    }
}
