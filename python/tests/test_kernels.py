"""Kernel-vs-reference correctness: the core L1 signal.

Every Pallas kernel is checked against the pure-jnp oracle in
``compile/kernels/ref.py`` — values, PUI, and gradients.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import packing
from compile.kernels import conv1d as cv
from compile.kernels import ref
from compile.kernels import selective_scan as ss


def make_inputs(seed, B, L, D, N, W=4):
    rng = np.random.default_rng(seed)
    return dict(
        x=jnp.array(rng.standard_normal((B, L, D)), jnp.float32),
        dt=jnp.array(rng.uniform(0.01, 0.2, (B, L, D)), jnp.float32),
        A=jnp.array(-rng.uniform(0.5, 2.0, (D, N)), jnp.float32),
        B=jnp.array(rng.standard_normal((B, L, N)), jnp.float32),
        C=jnp.array(rng.standard_normal((B, L, N)), jnp.float32),
        D=jnp.array(rng.standard_normal((D,)), jnp.float32),
        w=jnp.array(rng.standard_normal((W, D)), jnp.float32),
        bias=jnp.array(rng.standard_normal((D,)), jnp.float32),
    )


def pos_for(lengths_rows, L):
    return jnp.array(
        np.stack([packing.indices_for_lengths(r, L) for r in lengths_rows])
    )


LAYOUTS = [
    ("multi", [[7, 9, 5, 3], [24]]),
    ("single_seq", [[24], [24]]),
    ("all_singletons", [[1] * 24, [2] * 12]),
    ("with_pad_tail", [[10, 6], [20]]),
]


@pytest.mark.parametrize("mode", ["hillis", "blelloch"])
@pytest.mark.parametrize("name,rows", LAYOUTS)
def test_scan_masked_matches_ref(mode, name, rows):
    B, L, D, N = len(rows), 24, 8, 4
    inp = make_inputs(0, B, L, D, N)
    pos = pos_for(rows, L)
    a = jnp.exp(inp["dt"][..., None] * inp["A"][None, None])
    b = (inp["dt"] * inp["x"])[..., None] * inp["B"][:, :, None, :]
    h_ref = ref.segmented_scan_ref(a, b, pos)
    h = ss.scan_masked_pallas(a, b, pos, mode=mode, d_block=4)
    np.testing.assert_allclose(h, h_ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mode", ["hillis", "blelloch"])
@pytest.mark.parametrize("L", [1, 2, 3, 7, 16, 33, 64])
def test_scan_odd_lengths(mode, L):
    """Non-power-of-two L exercises Blelloch's internal padding."""
    B, D, N = 1, 4, 2
    inp = make_inputs(L, B, L, D, N)
    a = jnp.exp(inp["dt"][..., None] * inp["A"][None, None])
    b = (inp["dt"] * inp["x"])[..., None] * inp["B"][:, :, None, :]
    h_ref = ref.linear_scan_ref(a, b)
    h = ss.scan_plain_pallas(a, b, mode=mode, d_block=4)
    np.testing.assert_allclose(h, h_ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name,rows", LAYOUTS)
def test_ssm_packed_matches_ref(name, rows):
    B, L, D, N = len(rows), 24, 8, 4
    inp = make_inputs(1, B, L, D, N)
    pos = pos_for(rows, L)
    y_ref = ref.ssm_packed_ref(
        inp["x"], inp["dt"], inp["A"], inp["B"], inp["C"], inp["D"], pos
    )
    y = ss.ssm_packed(
        inp["x"], inp["dt"], inp["A"], inp["B"], inp["C"], inp["D"], pos
    )
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


def test_ssm_pui_against_per_sequence_oracle():
    rows = [[7, 9, 5, 3]]
    B, L, D, N = 1, 24, 8, 4
    inp = make_inputs(2, B, L, D, N)
    pos = pos_for(rows, L)
    y = ss.ssm_packed(
        inp["x"], inp["dt"], inp["A"], inp["B"], inp["C"], inp["D"], pos
    )
    per = ref.ssm_per_sequence(
        inp["x"][0], inp["dt"][0], inp["A"], inp["B"][0], inp["C"][0], inp["D"],
        rows[0],
    )
    np.testing.assert_allclose(y[0], per, rtol=1e-4, atol=1e-4)


def test_ssm_state_isolation_negative_control():
    """Without the index reset, outputs after a boundary must change —
    proving the mask is load-bearing."""
    rows = [[12, 12]]
    B, L, D, N = 1, 24, 8, 4
    inp = make_inputs(3, B, L, D, N)
    pos_good = pos_for(rows, L)
    pos_bad = jnp.arange(L, dtype=jnp.int32)[None, :]
    y_good = ss.ssm_packed(
        inp["x"], inp["dt"], inp["A"], inp["B"], inp["C"], inp["D"], pos_good
    )
    y_bad = ss.ssm_packed(
        inp["x"], inp["dt"], inp["A"], inp["B"], inp["C"], inp["D"], pos_bad
    )
    # first sequence identical, second differs
    np.testing.assert_allclose(y_good[0, :12], y_bad[0, :12], rtol=1e-6, atol=1e-6)
    assert float(jnp.abs(y_good[0, 12:] - y_bad[0, 12:]).max()) > 1e-4


@pytest.mark.parametrize("name,rows", LAYOUTS)
@pytest.mark.parametrize("W", [2, 3, 4])
def test_conv1d_packed_matches_ref(name, rows, W):
    B, L, D = len(rows), 24, 8
    inp = make_inputs(4, B, L, D, 4, W=W)
    pos = pos_for(rows, L)
    y_ref = ref.conv1d_packed_ref(inp["x"], inp["w"], inp["bias"], pos)
    y = cv.conv1d_packed(inp["x"], inp["w"], inp["bias"], pos)
    np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-5)


def test_conv1d_pui_against_per_sequence_oracle():
    rows = [[2, 9, 5, 8]]
    B, L, D = 1, 24, 8
    inp = make_inputs(5, B, L, D, 4)
    pos = pos_for(rows, L)
    y = cv.conv1d_packed(inp["x"], inp["w"], inp["bias"], pos)
    per = ref.conv1d_per_sequence(inp["x"][0], inp["w"], inp["bias"], rows[0])
    np.testing.assert_allclose(y[0], per, rtol=1e-5, atol=1e-5)


def test_conv1d_boundary_no_cross_sequence_reads():
    """First tokens of the 2nd sequence must be independent of the 1st
    sequence's tail (the red line in the paper's Fig 3b)."""
    rows = [[12, 12]]
    B, L, D = 1, 24, 4
    inp = make_inputs(6, B, L, D, 4)
    pos = pos_for(rows, L)
    y1 = cv.conv1d_packed(inp["x"], inp["w"], inp["bias"], pos)
    # perturb the first sequence's last token
    x2 = inp["x"].at[0, 11].add(100.0)
    y2 = cv.conv1d_packed(x2, inp["w"], inp["bias"], pos)
    np.testing.assert_allclose(y1[0, 12:], y2[0, 12:], rtol=0, atol=0)
    # within the first sequence the perturbation is visible
    assert float(jnp.abs(y1[0, 11] - y2[0, 11]).max()) > 1.0


def test_gradients_match_reference():
    rows = [[7, 9, 8]]
    B, L, D, N = 1, 24, 8, 4
    inp = make_inputs(7, B, L, D, N)
    pos = pos_for(rows, L)

    def loss_kernel(x, dt, w, bias, A, Bm, Cm, Dv):
        xc = cv.conv1d_packed(x, w, bias, pos)
        y = ss.ssm_packed(xc, dt, A, Bm, Cm, Dv, pos)
        return jnp.sum(jnp.tanh(y))

    def loss_ref(x, dt, w, bias, A, Bm, Cm, Dv):
        xc = ref.conv1d_packed_ref(x, w, bias, pos)
        y = ref.ssm_packed_ref(xc, dt, A, Bm, Cm, Dv, pos)
        return jnp.sum(jnp.tanh(y))

    args = (inp["x"], inp["dt"], inp["w"], inp["bias"], inp["A"], inp["B"],
            inp["C"], inp["D"])
    gk = jax.grad(loss_kernel, argnums=tuple(range(8)))(*args)
    gr = jax.grad(loss_ref, argnums=tuple(range(8)))(*args)
    for name, a, b in zip("x dt w bias A B C D".split(), gk, gr):
        np.testing.assert_allclose(
            a, b, rtol=2e-4, atol=2e-5, err_msg=f"grad {name}"
        )


def test_gradients_do_not_cross_boundaries():
    """dL/dx of sequence 1 must be zero when the loss only reads
    sequence 2's outputs — gradient isolation mirrors forward isolation."""
    rows = [[12, 12]]
    B, L, D, N = 1, 24, 8, 4
    inp = make_inputs(8, B, L, D, N)
    pos = pos_for(rows, L)

    def loss(x):
        xc = cv.conv1d_packed(x, inp["w"], inp["bias"], pos)
        y = ss.ssm_packed(
            xc, inp["dt"], inp["A"], inp["B"], inp["C"], inp["D"], pos
        )
        return jnp.sum(y[0, 12:] ** 2)  # only the 2nd sequence

    g = jax.grad(loss)(inp["x"])
    assert float(jnp.abs(g[0, :12]).max()) == 0.0, "gradient leaked backwards"
    assert float(jnp.abs(g[0, 12:]).max()) > 0.0


def test_scan_modes_agree():
    B, L, D, N = 2, 40, 8, 4
    inp = make_inputs(9, B, L, D, N)
    pos = pos_for([[13, 17, 10], [40]], L)
    a = jnp.exp(inp["dt"][..., None] * inp["A"][None, None])
    b = (inp["dt"] * inp["x"])[..., None] * inp["B"][:, :, None, :]
    h1 = ss.scan_masked_pallas(a, b, pos, mode="hillis", d_block=8)
    h2 = ss.scan_masked_pallas(a, b, pos, mode="blelloch", d_block=8)
    np.testing.assert_allclose(h1, h2, rtol=1e-5, atol=1e-5)


def test_d_block_tiling_invariance():
    """Grid tiling over channels must not change results."""
    B, L, D, N = 1, 16, 12, 4
    inp = make_inputs(10, B, L, D, N)
    pos = pos_for([[9, 7]], L)
    a = jnp.exp(inp["dt"][..., None] * inp["A"][None, None])
    b = (inp["dt"] * inp["x"])[..., None] * inp["B"][:, :, None, :]
    h_full = ss.scan_masked_pallas(a, b, pos, d_block=12)
    for blk in [1, 2, 3, 4, 6]:
        h_blk = ss.scan_masked_pallas(a, b, pos, d_block=blk)
        np.testing.assert_allclose(h_blk, h_full, rtol=1e-6, atol=1e-6)


def test_ssm_dense_equals_packed_with_arange():
    B, L, D, N = 2, 16, 4, 4
    inp = make_inputs(11, B, L, D, N)
    y1 = ss.ssm_dense(inp["x"], inp["dt"], inp["A"], inp["B"], inp["C"], inp["D"])
    pos = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None], (B, L))
    y2 = ss.ssm_packed(inp["x"], inp["dt"], inp["A"], inp["B"], inp["C"], inp["D"], pos)
    np.testing.assert_allclose(y1, y2, rtol=0, atol=0)
