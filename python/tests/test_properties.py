"""Hypothesis property sweeps: PUI over random shapes, dtypes and
boundary layouts (deliverable (c): property-based tests on invariants)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import packing
from compile.kernels import conv1d as cv
from compile.kernels import ref
from compile.kernels import selective_scan as ss


@st.composite
def packed_layout(draw, max_len=48):
    """A random row layout: sequence lengths that fit in pack_len."""
    pack_len = draw(st.integers(8, max_len))
    lengths = []
    remaining = pack_len
    while remaining > 0:
        if lengths and draw(st.booleans()):
            break
        n = draw(st.integers(1, remaining))
        lengths.append(n)
        remaining -= n
    return pack_len, lengths


def inputs_for(seed, B, L, D, N, W=4, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return dict(
        x=jnp.asarray(rng.standard_normal((B, L, D)), dtype),
        dt=jnp.asarray(rng.uniform(0.01, 0.2, (B, L, D)), dtype),
        A=jnp.asarray(-rng.uniform(0.5, 2.0, (D, N)), dtype),
        B=jnp.asarray(rng.standard_normal((B, L, N)), dtype),
        C=jnp.asarray(rng.standard_normal((B, L, N)), dtype),
        D=jnp.asarray(rng.standard_normal((D,)), dtype),
        w=jnp.asarray(rng.standard_normal((W, D)), dtype),
        bias=jnp.asarray(rng.standard_normal((D,)), dtype),
    )


@settings(max_examples=25, deadline=None)
@given(layout=packed_layout(), seed=st.integers(0, 2**16), mode=st.sampled_from(["hillis", "blelloch"]))
def test_pui_ssm_random_layouts(layout, seed, mode):
    pack_len, lengths = layout
    inp = inputs_for(seed, 1, pack_len, 4, 2)
    pos = jnp.array(packing.indices_for_lengths(lengths, pack_len))[None]
    y = ss.ssm_packed(
        inp["x"], inp["dt"], inp["A"], inp["B"], inp["C"], inp["D"], pos,
        mode=mode,
    )
    per = ref.ssm_per_sequence(
        inp["x"][0], inp["dt"][0], inp["A"], inp["B"][0], inp["C"][0],
        inp["D"], lengths,
    )
    used = sum(lengths)
    np.testing.assert_allclose(y[0, :used], per, rtol=2e-4, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(layout=packed_layout(), seed=st.integers(0, 2**16), W=st.integers(2, 5))
def test_pui_conv1d_random_layouts(layout, seed, W):
    pack_len, lengths = layout
    inp = inputs_for(seed, 1, pack_len, 4, 2, W=W)
    pos = jnp.array(packing.indices_for_lengths(lengths, pack_len))[None]
    y = cv.conv1d_packed(inp["x"], inp["w"], inp["bias"], pos)
    per = ref.conv1d_per_sequence(inp["x"][0], inp["w"], inp["bias"], lengths)
    used = sum(lengths)
    np.testing.assert_allclose(y[0, :used], per, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), L=st.integers(1, 40))
def test_scan_matches_serial_any_length(seed, L):
    inp = inputs_for(seed, 2, L, 4, 2)
    a = jnp.exp(inp["dt"][..., None] * inp["A"][None, None])
    b = (inp["dt"] * inp["x"])[..., None] * inp["B"][:, :, None, :]
    h_ref = ref.linear_scan_ref(a, b)
    h = ss.scan_plain_pallas(a, b, d_block=4)
    np.testing.assert_allclose(h, h_ref, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_pui_holds_in_bfloat16(seed):
    """dtype sweep: bf16 still satisfies PUI within its precision."""
    lengths = [9, 7, 4]
    L = 20
    inp = inputs_for(seed, 1, L, 4, 2, dtype=np.float32)
    inp = {k: v.astype(jnp.bfloat16) for k, v in inp.items()}
    pos = jnp.array(packing.indices_for_lengths(lengths, L))[None]
    y = ss.ssm_packed(
        inp["x"], inp["dt"], inp["A"], inp["B"], inp["C"], inp["D"], pos
    ).astype(jnp.float32)
    per = ref.ssm_per_sequence(
        inp["x"][0], inp["dt"][0], inp["A"], inp["B"][0], inp["C"][0],
        inp["D"], lengths,
    ).astype(jnp.float32)
    np.testing.assert_allclose(y[0, :20], per, rtol=0.1, atol=0.1)


@settings(max_examples=25, deadline=None)
@given(
    lengths=st.lists(st.integers(1, 30), min_size=0, max_size=8),
    pack_len_extra=st.integers(0, 16),
)
def test_pack_unpack_identity(lengths, pack_len_extra):
    """unpack(pack(S)) == S at the data level (paper §3.1)."""
    pack_len = sum(lengths) + pack_len_extra
    if pack_len == 0:
        pack_len = 1
    rng = np.random.default_rng(sum(lengths) + pack_len)
    seqs = [rng.integers(1, 100, size=n).astype(np.int32) for n in lengths]
    if any(n > pack_len for n in lengths):
        return
    pack = packing.pack_sequences(seqs, pack_len)
    toks = pack.tokens[..., None].astype(np.float32)
    pieces = packing.unpack(toks, pack)
    assert len(pieces) == len(seqs)
    for got, want in zip(pieces, seqs):
        np.testing.assert_array_equal(got[:, 0].astype(np.int32), want)


@settings(max_examples=25, deadline=None)
@given(lengths=st.lists(st.integers(1, 20), min_size=1, max_size=6))
def test_padding_rate_accounting(lengths):
    pack_len = max(sum(lengths), 1)
    rng = np.random.default_rng(0)
    seqs = [rng.integers(1, 9, size=n).astype(np.int32) for n in lengths]
    pack = packing.pack_sequences(seqs, pack_len)
    total_slots = pack.batch * pack.seq_len
    real = sum(lengths)
    assert abs(packing.padding_rate(pack) - (1 - real / total_slots)) < 1e-9
