"""L2 model tests: shapes, PUI at the full-model level, loss/grads,
optimizer semantics, and the AOT flat-argument contract."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import packing

CFG = M.MambaConfig(name="test", vocab_size=64, d_model=16, n_layers=2)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


def batch_for(lengths_rows, L, seed=0):
    rng = np.random.default_rng(seed)
    B = len(lengths_rows)
    tokens = np.zeros((B, L), np.int32)
    pos = np.zeros((B, L), np.int32)
    mask = np.zeros((B, L), np.float32)
    targets = np.zeros((B, L), np.int32)
    for r, lens in enumerate(lengths_rows):
        pos[r] = packing.indices_for_lengths(lens, L)
        off = 0
        for n in lens:
            toks = rng.integers(1, CFG.vocab_size, size=n)
            tokens[r, off : off + n] = toks
            targets[r, off : off + n - 1] = toks[1:]
            mask[r, off : off + n - 1] = 1.0
            off += n
    return (jnp.array(tokens), jnp.array(targets), jnp.array(pos), jnp.array(mask))


def test_param_shapes_and_count(params):
    shapes = M.param_shapes(CFG)
    assert set(params) == set(shapes)
    total = sum(int(np.prod(shapes[k])) for k in shapes)
    assert total == CFG.param_count()
    for k, p in params.items():
        assert p.shape == shapes[k], k
        assert bool(jnp.isfinite(p).all()), k


def test_forward_shapes(params):
    tokens, _, pos, _ = batch_for([[10, 6], [16]], 16)
    logits = M.forward(params, tokens, pos, CFG)
    assert logits.shape == (2, 16, CFG.vocab_size)
    assert bool(jnp.isfinite(logits).all())


def test_model_level_pui(params):
    """forward(pack(S)) == forward(S_i) per sequence — whole model."""
    lengths = [9, 7]
    L = 16
    tokens, _, pos, _ = batch_for([lengths], L, seed=3)
    packed_logits = M.forward(params, tokens, pos, CFG)

    off = 0
    for n in lengths:
        solo_toks = tokens[:, off : off + n]
        solo_pos = jnp.arange(n, dtype=jnp.int32)[None]
        solo = M.forward(params, solo_toks, solo_pos, CFG)
        np.testing.assert_allclose(
            packed_logits[0, off : off + n],
            solo[0],
            rtol=5e-4,
            atol=5e-4,
        )
        off += n


def test_loss_is_scalar_and_masked(params):
    tokens, targets, pos, mask = batch_for([[10, 6], [16]], 16, seed=4)
    loss = M.loss_fn(params, tokens, targets, pos, mask, CFG)
    assert loss.shape == ()
    # fully-masked batch gives 0 loss (no targets)
    zero = M.loss_fn(params, tokens, targets, pos, jnp.zeros_like(mask), CFG)
    assert float(zero) == 0.0
    # untrained model: loss near ln(vocab)
    assert abs(float(loss) - np.log(CFG.vocab_size)) < 1.0


def test_padding_does_not_affect_loss(params):
    """Adding padding slots must not change the loss (they are masked and
    isolated)."""
    lengths = [9, 5]
    t14, g14, p14, m14 = batch_for([lengths], 14, seed=5)
    loss14 = M.loss_fn(params, t14, g14, p14, m14, CFG)
    # same data in a longer row
    t20 = jnp.zeros((1, 20), jnp.int32).at[:, :14].set(t14)
    g20 = jnp.zeros((1, 20), jnp.int32).at[:, :14].set(g14)
    p20 = jnp.array(packing.indices_for_lengths(lengths, 20))[None]
    m20 = jnp.zeros((1, 20), jnp.float32).at[:, :14].set(m14)
    loss20 = M.loss_fn(params, t20, g20, p20, m20, CFG)
    np.testing.assert_allclose(float(loss14), float(loss20), rtol=1e-5)


def test_grads_flow_to_all_params(params):
    tokens, targets, pos, mask = batch_for([[12, 4]], 16, seed=6)
    loss, grads = jax.value_and_grad(M.loss_fn)(
        params, tokens, targets, pos, mask, CFG
    )
    assert float(loss) > 0
    for k, g in grads.items():
        assert bool(jnp.isfinite(g).all()), k
        assert float(jnp.abs(g).max()) > 0, f"no gradient reaches {k}"


def test_adamw_moves_params_and_decays(params):
    grads = {k: jnp.ones_like(v) for k, v in params.items()}
    m0 = {k: jnp.zeros_like(v) for k, v in params.items()}
    opt = M.AdamWConfig(lr=1e-2, weight_decay=0.5)
    new_p, new_m, new_v = M.adamw_update(params, m0, m0, grads, jnp.float32(1), opt)
    for k in params:
        assert float(jnp.abs(new_p[k] - params[k]).max()) > 0, k
        assert float(jnp.abs(new_m[k]).max()) > 0
        assert float(jnp.abs(new_v[k]).max()) > 0
    # decayed matrices move further than undecayed vectors of equal grad
    dk = float(jnp.abs(new_p["layers.0.in_proj"] - params["layers.0.in_proj"]).mean())
    dv = float(jnp.abs(new_p["layers.0.conv_b"] - params["layers.0.conv_b"]).mean())
    assert dk > dv


def test_train_step_decreases_loss_on_fixed_batch(params):
    opt = M.AdamWConfig(lr=3e-3)
    step_fn = jax.jit(M.make_train_step(CFG, opt))
    tokens, targets, pos, mask = batch_for([[12, 4], [16]], 16, seed=7)
    p = params
    m = {k: jnp.zeros_like(v) for k, v in p.items()}
    v = {k: jnp.zeros_like(x) for k, x in p.items()}
    losses = []
    for i in range(8):
        p, m, v, loss = step_fn(p, m, v, jnp.float32(i + 1), tokens, targets, pos, mask)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_flat_wrappers_round_trip():
    """The AOT flat-argument contract: flat wrapper == dict API."""
    from compile import aot

    order = M.param_order(CFG)
    params = M.init_params(CFG, seed=1)
    flat = [params[k] for k in order]
    tokens, targets, pos, mask = batch_for([[10, 6]], 16, seed=8)

    fwd = aot.flat_forward(CFG)
    (logits_flat,) = fwd(*flat, tokens, pos)
    logits_dict = M.forward(params, tokens, pos, CFG)
    np.testing.assert_allclose(logits_flat, logits_dict, rtol=1e-6, atol=1e-6)

    gr = aot.flat_grads(CFG)
    outs = gr(*flat, tokens, targets, pos, mask)
    loss_flat = outs[0]
    loss_dict, grads = jax.value_and_grad(M.loss_fn)(
        params, tokens, targets, pos, mask, CFG
    )
    np.testing.assert_allclose(loss_flat, loss_dict, rtol=1e-6)
    for name, g_flat in zip(order, outs[1:]):
        np.testing.assert_allclose(g_flat, grads[name], rtol=1e-5, atol=1e-6,
                                   err_msg=name)


def test_scan_mode_config_is_respected():
    cfg_h = M.MambaConfig(name="h", vocab_size=64, d_model=16, n_layers=1,
                          scan_mode="hillis")
    cfg_b = M.MambaConfig(name="b", vocab_size=64, d_model=16, n_layers=1,
                          scan_mode="blelloch")
    p = M.init_params(cfg_h, seed=2)
    tokens, _, pos, _ = batch_for([[10, 6]], 16, seed=9)
    lh = M.forward(p, tokens, pos, cfg_h)
    lb = M.forward(p, tokens, pos, cfg_b)
    np.testing.assert_allclose(lh, lb, rtol=1e-4, atol=1e-4)


def test_preset_param_counts():
    assert 100e6 < M.MAMBA_110M.param_count() < 180e6
    assert 1.2e9 < M.MAMBA_1_4B.param_count() < 1.6e9
    assert 2.5e9 < M.MAMBA_2_8B.param_count() < 3.1e9
