"""AOT pipeline tests: HLO text generation, manifest integrity, and the
generated artifacts' signatures (runs a tiny in-process build)."""

import json
import os

import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    b = aot.Builder(str(out))
    cfg = M.MambaConfig(name="unit", vocab_size=64, d_model=16, n_layers=1)
    b.add_config(cfg)
    order = M.param_order(cfg)
    shapes = M.param_shapes(cfg)
    pspecs = [aot.spec(shapes[n]) for n in order]
    b.build(
        "forward_unit_b1x8",
        "forward",
        aot.flat_forward(cfg),
        pspecs + [aot.spec((1, 8), jnp.int32), aot.spec((1, 8), jnp.int32)],
        {"config": "unit", "batch": 1, "seq_len": 8},
    )
    b.build("init_unit", "init", aot.flat_init(cfg, seed=3), [], {"config": "unit"})
    b.finish()
    return str(out), cfg


def test_hlo_text_is_parseable_hlo(built):
    out, _ = built
    text = open(os.path.join(out, "forward_unit_b1x8.hlo.txt")).read()
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # interpret-mode pallas must lower to plain HLO: no Mosaic custom calls
    assert "mosaic" not in text.lower()


def test_manifest_structure(built):
    out, cfg = built
    man = json.load(open(os.path.join(out, "manifest.json")))
    assert man["version"] == 1
    names = {a["name"] for a in man["artifacts"]}
    assert names == {"forward_unit_b1x8", "init_unit"}
    fwd = next(a for a in man["artifacts"] if a["name"] == "forward_unit_b1x8")
    # inputs: params + tokens + pos
    assert len(fwd["inputs"]) == len(M.param_order(cfg)) + 2
    assert fwd["inputs"][-1]["dtype"] == "int32"
    assert fwd["outputs"][0]["shape"] == [1, 8, cfg.vocab_size]
    # params section records the interchange order
    porder = [p["name"] for p in man["params"]["unit"]]
    assert porder == M.param_order(cfg)
    assert man["configs"]["unit"]["param_count"] == cfg.param_count()


def test_init_artifact_has_no_inputs(built):
    out, cfg = built
    man = json.load(open(os.path.join(out, "manifest.json")))
    init = next(a for a in man["artifacts"] if a["name"] == "init_unit")
    assert init["inputs"] == []
    assert len(init["outputs"]) == len(M.param_order(cfg))


def test_real_manifest_if_present():
    """When `make artifacts` has run, sanity-check the shipped manifest."""
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    man = json.load(open(path))
    kinds = {a["kind"] for a in man["artifacts"]}
    assert {"train_step", "forward", "grads", "adam_apply", "init",
            "ssm_op", "op_gemm", "op_conv1d", "op_ssm", "op_norm"} <= kinds
    # every artifact file exists
    d = os.path.dirname(path)
    for a in man["artifacts"]:
        assert os.path.exists(os.path.join(d, a["file"])), a["file"]
    # fig2 sweep covers pow2 and non-pow2 lengths in both scan modes
    fig2 = [a for a in man["artifacts"] if a["kind"] == "ssm_op"]
    lens = {a["seq_len"] for a in fig2}
    assert {256, 512, 1024, 2048, 4096} <= lens
    assert any(l & (l - 1) for l in lens), "need non-pow2 lengths"
    modes = {a["mode"] for a in fig2}
    assert modes == {"blelloch", "hillis"}
