"""§5 future-work extension: sequences split across pack rows with state
carry.  Chunked forward must equal the unchunked forward exactly."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M

CFG = M.MambaConfig(name="chunk", vocab_size=64, d_model=16, n_layers=2)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=5)


def test_chunked_forward_matches_full(params):
    rng = np.random.default_rng(0)
    L = 32
    tokens = jnp.array(rng.integers(1, 64, size=(1, L)), jnp.int32)
    pos_full = jnp.arange(L, dtype=jnp.int32)[None]
    full = M.forward(params, tokens, pos_full, CFG)

    # two chunks of 16; the second chunk's position indices continue
    states = M.init_chunk_state(CFG, 1)
    out = []
    for c in range(2):
        sl = slice(16 * c, 16 * (c + 1))
        logits, states = M.forward_chunked(
            params, tokens[:, sl], pos_full[:, sl], CFG, states
        )
        out.append(logits)
    chunked = jnp.concatenate(out, axis=1)
    np.testing.assert_allclose(chunked, full, rtol=2e-4, atol=2e-4)


def test_chunked_three_uneven_chunks(params):
    rng = np.random.default_rng(1)
    L = 40
    tokens = jnp.array(rng.integers(1, 64, size=(1, L)), jnp.int32)
    pos_full = jnp.arange(L, dtype=jnp.int32)[None]
    full = M.forward(params, tokens, pos_full, CFG)

    states = M.init_chunk_state(CFG, 1)
    out = []
    for lo, hi in [(0, 8), (8, 24), (24, 40)]:
        logits, states = M.forward_chunked(
            params, tokens[:, lo:hi], pos_full[:, lo:hi], CFG, states
        )
        out.append(logits)
    chunked = jnp.concatenate(out, axis=1)
    np.testing.assert_allclose(chunked, full, rtol=2e-4, atol=2e-4)


def test_fresh_start_chunk_ignores_carried_state(params):
    """A chunk whose position indices start at 0 must give the same output
    whether the carried state is zero or garbage."""
    rng = np.random.default_rng(2)
    tokens = jnp.array(rng.integers(1, 64, size=(1, 16)), jnp.int32)
    pos = jnp.arange(16, dtype=jnp.int32)[None]

    zero_states = M.init_chunk_state(CFG, 1)
    junk_states = [
        {"h": s["h"] + 37.0, "conv": s["conv"] - 11.0} for s in zero_states
    ]
    a, _ = M.forward_chunked(params, tokens, pos, CFG, zero_states)
    b, _ = M.forward_chunked(params, tokens, pos, CFG, junk_states)
    np.testing.assert_allclose(a, b, rtol=0, atol=0)


def test_chunked_packed_mix(params):
    """A chunk can both continue one sequence AND contain fresh packed
    sequences after it — state flows only into the continuation."""
    rng = np.random.default_rng(3)
    # original: one 24-token sequence + one fresh 8-token sequence
    seq_a = jnp.array(rng.integers(1, 64, size=24), jnp.int32)
    seq_b = jnp.array(rng.integers(1, 64, size=8), jnp.int32)

    # reference: run each alone
    full_a = M.forward(params, seq_a[None], jnp.arange(24, dtype=jnp.int32)[None], CFG)
    full_b = M.forward(params, seq_b[None], jnp.arange(8, dtype=jnp.int32)[None], CFG)

    # chunk 1: first 16 of A.  chunk 2: last 8 of A (continuing) + all of B
    states = M.init_chunk_state(CFG, 1)
    c1, states = M.forward_chunked(
        params, seq_a[None, :16], jnp.arange(16, dtype=jnp.int32)[None], CFG, states
    )
    chunk2_tokens = jnp.concatenate([seq_a[16:], seq_b])[None]
    chunk2_pos = jnp.concatenate(
        [jnp.arange(16, 24, dtype=jnp.int32), jnp.arange(8, dtype=jnp.int32)]
    )[None]
    c2, _ = M.forward_chunked(params, chunk2_tokens, chunk2_pos, CFG, states)

    np.testing.assert_allclose(c1, full_a[:, :16], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(c2[:, :8], full_a[:, 16:], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(c2[:, 8:], full_b, rtol=2e-4, atol=2e-4)
