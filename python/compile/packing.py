"""Reference pack()/unpack() semantics shared by the kernels, tests and AOT.

This is the *mathematical* definition the paper's Packing-Unpacking
Invariance (PUI) property is stated against (paper §3.1):

    f(S) == unpack(f(pack(S)))

``pack`` concatenates variable-length sequences along the sequence dimension
into fixed-length rows of a ``(B, L)`` tensor and records, per packed token,
its *position index* — the token's offset inside its own original sequence.
A position index of 0 therefore marks a sequence start, which is exactly the
signal the modified sequence-wise operators (conv1d / selective scan) use to
stop information from crossing sequence boundaries.

The rust coordinator re-implements this (``rust/src/packing/``) for the hot
path; this module is the slow, obviously-correct oracle used to pin the
semantics in pytest, and by ``aot.py`` to build example inputs.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence

import numpy as np


@dataclasses.dataclass
class Pack:
    """One packed batch row set.

    tokens:           (B, L) int32 — packed token ids, 0-padded at row tails.
    position_indices: (B, L) int32 — offset of each token within its original
                      sequence; 0 marks a sequence start.  Padding tokens are
                      a degenerate "sequence" of their own: the first padding
                      slot has position index 0 (resetting the SSM state) and
                      the rest count up, so padded garbage can never
                      contaminate a real sequence and is excluded via
                      ``loss_mask``.
    segment_ids:      (B, L) int32 — 1-based id of the original sequence each
                      token came from; 0 for padding slots.
    loss_mask:        (B, L) float32 — 1.0 on real tokens, 0.0 on padding.
    lengths:          per row, the original sequence lengths packed into it.
    """

    tokens: np.ndarray
    position_indices: np.ndarray
    segment_ids: np.ndarray
    loss_mask: np.ndarray
    lengths: List[List[int]]

    @property
    def batch(self) -> int:
        return self.tokens.shape[0]

    @property
    def seq_len(self) -> int:
        return self.tokens.shape[1]


def indices_for_lengths(lengths: Sequence[int], pack_len: int) -> np.ndarray:
    """position_indices for one packed row holding ``lengths`` sequences."""
    total = sum(lengths)
    if total > pack_len:
        raise ValueError(f"lengths {lengths} overflow pack_len {pack_len}")
    idx = np.zeros(pack_len, dtype=np.int32)
    off = 0
    for n in lengths:
        idx[off : off + n] = np.arange(n, dtype=np.int32)
        off += n
    # padding tail: its own segment, position indices counting from 0
    if off < pack_len:
        idx[off:] = np.arange(pack_len - off, dtype=np.int32)
    return idx


def segment_ids_for_lengths(lengths: Sequence[int], pack_len: int) -> np.ndarray:
    seg = np.zeros(pack_len, dtype=np.int32)
    off = 0
    for i, n in enumerate(lengths):
        seg[off : off + n] = i + 1
        off += n
    return seg


def pack_sequences(
    sequences: Iterable[np.ndarray], pack_len: int, rows: int | None = None
) -> Pack:
    """Streaming first-fit packer (paper §5 'received order' scheme).

    Appends each sequence to the current row; seals the row when the next
    sequence does not fit.  This mirrors ``rust/src/packing/streaming.rs``.
    """
    seqs = [np.asarray(s, dtype=np.int32) for s in sequences]
    for s in seqs:
        if s.ndim != 1:
            raise ValueError("sequences must be 1-D token arrays")
        if len(s) > pack_len:
            raise ValueError(f"sequence of length {len(s)} exceeds pack_len {pack_len}")
    row_lengths: List[List[int]] = [[]]
    row_tokens: List[List[np.ndarray]] = [[]]
    for s in seqs:
        used = sum(row_lengths[-1])
        if used + len(s) > pack_len:
            row_lengths.append([])
            row_tokens.append([])
        row_lengths[-1].append(len(s))
        row_tokens[-1].append(s)
    if rows is not None:
        while len(row_lengths) < rows:
            row_lengths.append([])
            row_tokens.append([])
        if len(row_lengths) > rows:
            raise ValueError(f"needs {len(row_lengths)} rows, caller allows {rows}")

    b = len(row_lengths)
    tokens = np.zeros((b, pack_len), dtype=np.int32)
    pos = np.zeros((b, pack_len), dtype=np.int32)
    seg = np.zeros((b, pack_len), dtype=np.int32)
    mask = np.zeros((b, pack_len), dtype=np.float32)
    for r, (lens, toks) in enumerate(zip(row_lengths, row_tokens)):
        if toks:
            flat = np.concatenate(toks)
            tokens[r, : len(flat)] = flat
            mask[r, : len(flat)] = 1.0
        pos[r] = indices_for_lengths(lens, pack_len)
        seg[r] = segment_ids_for_lengths(lens, pack_len)
    return Pack(tokens, pos, seg, mask, row_lengths)


def unpack(values: np.ndarray, pack: Pack) -> List[np.ndarray]:
    """Inverse of pack() applied to per-token outputs (B, L, ...)."""
    out: List[np.ndarray] = []
    for r, lens in enumerate(pack.lengths):
        off = 0
        for n in lens:
            out.append(np.asarray(values[r, off : off + n]))
            off += n
    return out


def padding_rate(pack: Pack) -> float:
    """Fraction of packed slots that are padding (paper §2.1 / §5 metric)."""
    return 1.0 - float(pack.loss_mask.mean())
