"""AOT compiler: lower every entry point to HLO *text* + manifest.json.

HLO text (NOT ``lowered.compile()`` / proto ``.serialize()``) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects; the HLO text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every lowered function takes a *flat* argument list (no pytrees) so the HLO
parameter order is exactly the order recorded in the manifest — this is the
interchange contract with ``rust/src/runtime/``.

Usage:  cd python && python -m compile.aot --out ../artifacts [--only tiny]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.selective_scan import ssm_packed
from .kernels.conv1d import conv1d_packed


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape: Sequence[int], dtype=jnp.float32) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _io_spec(specs) -> List[Dict]:
    return [
        {"shape": list(s.shape), "dtype": s.dtype.name}
        for s in specs
    ]


class Builder:
    """Collects artifact builds, writes .hlo.txt files and the manifest."""

    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest: Dict = {
            "version": 1,
            "configs": {},
            "params": {},
            "artifacts": [],
        }

    def add_config(self, cfg: M.MambaConfig):
        self.manifest["configs"][cfg.name] = {
            "vocab_size": cfg.vocab_size,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "d_state": cfg.d_state,
            "d_conv": cfg.d_conv,
            "expand": cfg.expand,
            "dt_rank": cfg.dt_rank,
            "d_inner": cfg.d_inner,
            "param_count": cfg.param_count(),
            "scan_mode": cfg.scan_mode,
        }
        shapes = M.param_shapes(cfg)
        self.manifest["params"][cfg.name] = [
            {"name": n, "shape": list(shapes[n])} for n in M.param_order(cfg)
        ]

    def build(
        self,
        name: str,
        kind: str,
        fn: Callable,
        in_specs: Sequence[jax.ShapeDtypeStruct],
        meta: Dict | None = None,
    ):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        out_shapes = lowered.out_info
        out_specs = [
            spec(o.shape, o.dtype) for o in jax.tree_util.tree_leaves(out_shapes)
        ]
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        entry = {
            "name": name,
            "file": fname,
            "kind": kind,
            "inputs": _io_spec(in_specs),
            "outputs": _io_spec(out_specs),
        }
        entry.update(meta or {})
        self.manifest["artifacts"].append(entry)
        print(
            f"  [{time.time()-t0:6.1f}s] {name}: {len(text)/1e6:.2f} MB, "
            f"{len(in_specs)} inputs, {len(out_specs)} outputs"
        )

    def finish(self):
        path = os.path.join(self.out_dir, "manifest.json")
        # merge with an existing manifest so partial builds (--only ...)
        # never drop other artifacts' entries
        if os.path.exists(path):
            with open(path) as f:
                old = json.load(f)
            if old.get("version") == self.manifest["version"]:
                fresh = {a["name"] for a in self.manifest["artifacts"]}
                kept = [
                    a
                    for a in old.get("artifacts", [])
                    if a["name"] not in fresh
                    and os.path.exists(os.path.join(self.out_dir, a["file"]))
                ]
                self.manifest["artifacts"] = kept + self.manifest["artifacts"]
                for key in ("configs", "params"):
                    merged = dict(old.get(key, {}))
                    merged.update(self.manifest[key])
                    self.manifest[key] = merged
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"wrote {path} ({len(self.manifest['artifacts'])} artifacts)")


# ---------------------------------------------------------------------------
# Flat-argument wrappers (the interchange contract).
# ---------------------------------------------------------------------------


def flat_train_step(cfg: M.MambaConfig, opt: M.AdamWConfig):
    order = M.param_order(cfg)
    np_ = len(order)
    step_fn = M.make_train_step(cfg, opt)

    def fn(*args):
        params = dict(zip(order, args[:np_]))
        m = dict(zip(order, args[np_ : 2 * np_]))
        v = dict(zip(order, args[2 * np_ : 3 * np_]))
        step, tokens, targets, pos, mask = args[3 * np_ :]
        new_p, new_m, new_v, loss = step_fn(
            params, m, v, step, tokens, targets, pos, mask
        )
        return (
            tuple(new_p[k] for k in order)
            + tuple(new_m[k] for k in order)
            + tuple(new_v[k] for k in order)
            + (loss,)
        )

    return fn


def flat_grads(cfg: M.MambaConfig):
    order = M.param_order(cfg)
    np_ = len(order)
    grads_fn = M.make_grads_fn(cfg)

    def fn(*args):
        params = dict(zip(order, args[:np_]))
        tokens, targets, pos, mask = args[np_:]
        loss, grads = grads_fn(params, tokens, targets, pos, mask)
        return (loss,) + tuple(grads[k] for k in order)

    return fn


def flat_adam_apply(cfg: M.MambaConfig, opt: M.AdamWConfig):
    order = M.param_order(cfg)
    np_ = len(order)

    def fn(*args):
        params = dict(zip(order, args[:np_]))
        m = dict(zip(order, args[np_ : 2 * np_]))
        v = dict(zip(order, args[2 * np_ : 3 * np_]))
        step = args[3 * np_]
        grads = dict(zip(order, args[3 * np_ + 1 :]))
        new_p, new_m, new_v = M.adamw_update(params, m, v, grads, step, opt)
        return (
            tuple(new_p[k] for k in order)
            + tuple(new_m[k] for k in order)
            + tuple(new_v[k] for k in order)
        )

    return fn


def flat_forward(cfg: M.MambaConfig):
    order = M.param_order(cfg)
    np_ = len(order)

    def fn(*args):
        params = dict(zip(order, args[:np_]))
        tokens, pos = args[np_:]
        return (M.forward(params, tokens, pos, cfg),)

    return fn


def flat_init(cfg: M.MambaConfig, seed: int):
    """Parameter initialization as an artifact: rust asks XLA to initialize
    (no numerics duplicated on the rust side).  Zero-input function."""
    order = M.param_order(cfg)

    def fn():
        params = M.init_params(cfg, seed)
        return tuple(params[k] for k in order)

    return fn


# ---------------------------------------------------------------------------
# Standalone operators (Fig 2 / Fig 6 benches).
# ---------------------------------------------------------------------------


def ssm_op(D: int, N: int, mode: str):
    def fn(x, dt, A, B, C, Dv, pos):
        return (ssm_packed(x, dt, A, B, C, Dv, pos, mode=mode),)

    return fn


def conv_op():
    def fn(x, w, b, pos):
        return (conv1d_packed(x, w, b, pos),)

    return fn


def gemm_op(dtype):
    def fn(x, w):
        y = x.astype(dtype) @ w.astype(dtype)
        return (y.astype(jnp.float32),)

    return fn


def norm_op(eps: float = 1e-5):
    def fn(x, w):
        return (M.rms_norm(x, w, eps),)

    return fn


# ---------------------------------------------------------------------------
# The artifact set.  Geometry notes:
#   - CPU-scale corpus lengths are the paper's divided by 8 (paper: 57-2048
#     mean 646 → here 8-256 mean ~81), so pack_len 512 plays the role the
#     paper's 4096 does.  Fig 2/6 operator shapes are chosen so the a-plane
#     (B·L·D·N floats) stays CPU-sized; see DESIGN.md §Hardware-Adaptation.
# ---------------------------------------------------------------------------

TRAIN_GEOM = {
    # cfg: (pack_rows, pack_len, pad_rows, pad_len, single_buckets)
    #
    # CPU adaptation (§Perf): pack_len equals the corpus max length rather
    # than 2× it.  Interpret-mode scans execute their ladder passes
    # serially, so per-token cost grows ~log L with pack length; on a GPU
    # the ladder is parallel across L and longer packs win (pack_len 4096
    # = 2× max, as the paper uses) — that side lives in the perf model.
    "tiny": (4, 128, 4, 128, [32, 64, 128]),
    "small": (4, 256, 4, 256, [64, 128, 256]),
}

FIG2_LENS = [256, 320, 384, 448, 512, 640, 768, 896, 1024, 1536, 2048, 3072, 4096]
FIG2_D, FIG2_N = 256, 16

# Fig 6 operator geometry ("1.4B-scaled"): d_model 128 → d_inner 256.
FIG6 = {
    "d_model": 128,
    "d_inner": 256,
    "d_state": 16,
    "d_conv": 4,
    # padding scheme: 3 rows × max-len 1024 (one sequence per row);
    # pack scheme: 1 row × 2048 densely packed.
    "padding": (3, 1024),
    "pack": (1, 2048),
}


def build_model_artifacts(b: Builder, cfg: M.MambaConfig, opt: M.AdamWConfig):
    b.add_config(cfg)
    order = M.param_order(cfg)
    shapes = M.param_shapes(cfg)
    pspecs = [spec(shapes[n]) for n in order]
    rows, plen, prows, plen_pad, buckets = TRAIN_GEOM[cfg.name]

    def batch_specs(bsz, L):
        return [
            spec((), jnp.float32),  # step
            spec((bsz, L), jnp.int32),  # tokens
            spec((bsz, L), jnp.int32),  # targets
            spec((bsz, L), jnp.int32),  # position_indices
            spec((bsz, L), jnp.float32),  # loss_mask
        ]

    geoms = [("pack", rows, plen), ("padding", prows, plen_pad)] + [
        ("single", 1, l) for l in buckets
    ]
    for scheme, bsz, L in geoms:
        b.build(
            f"train_step_{cfg.name}_{scheme}_b{bsz}x{L}",
            "train_step",
            flat_train_step(cfg, opt),
            pspecs * 3 + batch_specs(bsz, L),
            {"config": cfg.name, "batch": bsz, "seq_len": L, "scheme": scheme,
             "n_params": len(order)},
        )

    # forward: pack geometry + single-sequence buckets (PUI check from rust)
    for bsz, L in [(rows, plen)] + [(1, l) for l in buckets]:
        b.build(
            f"forward_{cfg.name}_b{bsz}x{L}",
            "forward",
            flat_forward(cfg),
            pspecs + [spec((bsz, L), jnp.int32), spec((bsz, L), jnp.int32)],
            {"config": cfg.name, "batch": bsz, "seq_len": L,
             "n_params": len(order)},
        )

    # data-parallel path: per-worker grads + leader-side optimizer apply
    b.build(
        f"grads_{cfg.name}_b{rows}x{plen}",
        "grads",
        flat_grads(cfg),
        pspecs + batch_specs(rows, plen)[1:],
        {"config": cfg.name, "batch": rows, "seq_len": plen,
         "n_params": len(order)},
    )
    b.build(
        f"adam_apply_{cfg.name}",
        "adam_apply",
        flat_adam_apply(cfg, opt),
        pspecs * 3 + [spec((), jnp.float32)] + pspecs,
        {"config": cfg.name, "n_params": len(order)},
    )
    b.build(
        f"init_{cfg.name}",
        "init",
        flat_init(cfg, seed=42),
        [],
        {"config": cfg.name, "n_params": len(order), "seed": 42},
    )


def build_fig2_artifacts(b: Builder, lens=None):
    D, N = FIG2_D, FIG2_N
    for L in lens or FIG2_LENS:
        for mode in ("blelloch", "hillis"):
            b.build(
                f"ssm_op_{mode}_L{L}",
                "ssm_op",
                ssm_op(D, N, mode),
                [
                    spec((1, L, D)),  # x
                    spec((1, L, D)),  # dt
                    spec((D, N)),  # A
                    spec((1, L, N)),  # B
                    spec((1, L, N)),  # C
                    spec((D,)),  # D
                    spec((1, L), jnp.int32),  # pos
                ],
                {"seq_len": L, "d_inner": D, "d_state": N, "mode": mode},
            )


def build_fig6_artifacts(b: Builder):
    di, n, w = FIG6["d_inner"], FIG6["d_state"], FIG6["d_conv"]
    dm = FIG6["d_model"]
    for scheme in ("padding", "pack"):
        bsz, L = FIG6[scheme]
        T = bsz * L
        meta = {"scheme": scheme, "batch": bsz, "seq_len": L, "tokens": T}
        # GEMM (in_proj shape), f32 and bf16 — the paper's bf16/f32 split
        for dt_name, dt in (("f32", jnp.float32), ("bf16", jnp.bfloat16)):
            b.build(
                f"op_gemm_{scheme}_{dt_name}",
                "op_gemm",
                gemm_op(dt),
                [spec((T, dm)), spec((dm, 2 * di))],
                {**meta, "dtype": dt_name, "m": T, "k": dm, "n": 2 * di},
            )
        b.build(
            f"op_conv1d_{scheme}",
            "op_conv1d",
            conv_op(),
            [spec((bsz, L, di)), spec((w, di)), spec((di,)),
             spec((bsz, L), jnp.int32)],
            meta,
        )
        b.build(
            f"op_ssm_{scheme}",
            "op_ssm",
            ssm_op(di, n, "blelloch"),
            [spec((bsz, L, di)), spec((bsz, L, di)), spec((di, n)),
             spec((bsz, L, n)), spec((bsz, L, n)), spec((di,)),
             spec((bsz, L), jnp.int32)],
            meta,
        )
        b.build(
            f"op_norm_{scheme}",
            "op_norm",
            norm_op(),
            [spec((T, dm)), spec((dm,))],
            meta,
        )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated subset: tiny,small,fig2,fig6",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    def want(k):
        return only is None or k in only

    b = Builder(args.out)
    opt = M.AdamWConfig()
    t0 = time.time()
    if want("tiny"):
        build_model_artifacts(b, M.TINY, opt)
    if want("small"):
        build_model_artifacts(b, M.SMALL, opt)
    if want("fig2"):
        build_fig2_artifacts(b)
    if want("fig6"):
        build_fig6_artifacts(b)
    b.finish()
    print(f"total {time.time()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
