"""L2: the Mamba language model (fwd/bwd) built on the Pallas kernels.

A faithful Mamba-1 block (Gu & Dao 2023), with the PackMamba modifications
threaded through: every sequence-wise operator (conv1d, selective scan)
takes ``position_indices`` so that packed sequences never exchange state
(paper §3.2-§3.4).  All *element-wise* (silu) and *token-wise* (linear,
RMSNorm) operators are PUI-trivially-safe and stay in plain jnp.

The same forward serves all three batching schemes of the paper's
evaluation — they differ only in batch geometry and in the index plane the
rust coordinator feeds:

  single-sequence : B=1, L=natural length (bucketed), pos = arange
  padding         : B=rows, L=max length, one sequence per row
  pack            : B=rows, L=pack_len, many sequences per row + indices

Everything here runs at build time only; ``aot.py`` lowers the jitted
functions to HLO text artifacts that the rust runtime executes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.conv1d import conv1d_packed
from .kernels.selective_scan import ssm_packed

Params = Dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    """Model hyperparameters.  Presets mirror the paper's table of models
    (110M/1.4B/2.8B) plus CPU-scale configs used for real execution."""

    name: str
    vocab_size: int
    d_model: int
    n_layers: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    norm_eps: float = 1e-5
    scan_mode: str = "blelloch"

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return math.ceil(self.d_model / 16)

    def param_count(self) -> int:
        """Exact parameter count (used by config tests and the perf model)."""
        per_layer = (
            self.d_model * 2 * self.d_inner  # in_proj
            + self.d_conv * self.d_inner  # conv w
            + self.d_inner  # conv bias
            + self.d_inner * (self.dt_rank + 2 * self.d_state)  # x_proj
            + self.dt_rank * self.d_inner  # dt_proj
            + self.d_inner  # dt_bias
            + self.d_inner * self.d_state  # A_log
            + self.d_inner  # D
            + self.d_inner * self.d_model  # out_proj
            + self.d_model  # norm weight
        )
        return self.vocab_size * self.d_model + self.n_layers * per_layer + self.d_model


# CPU-executable presets (artifacts are built for these).  Training
# artifacts use the depth-efficient Hillis-Steele schedule: under
# interpret=True every ladder pass executes serially, so halving the pass
# count (log2 L vs Blelloch's 2·log2 L) nearly halves the scan cost
# (§Perf, EXPERIMENTS.md).  The work-efficient Blelloch schedule — the
# paper's Algorithm 2 — is kept for the Fig 2/Fig 6 operator artifacts
# and the ablation; on a real TPU it wins instead (DESIGN.md §9).
TINY = MambaConfig(name="tiny", vocab_size=512, d_model=64, n_layers=2,
                   scan_mode="hillis")
SMALL = MambaConfig(name="small", vocab_size=1024, d_model=128, n_layers=4,
                    scan_mode="hillis")
# ...and the paper's A100-scale models (perfmodel only, no artifacts).
MAMBA_110M = MambaConfig(name="110m", vocab_size=50280, d_model=1024, n_layers=16)
MAMBA_1_4B = MambaConfig(name="1.4b", vocab_size=50280, d_model=2048, n_layers=48)
MAMBA_2_8B = MambaConfig(name="2.8b", vocab_size=50280, d_model=2560, n_layers=64)

CONFIGS = {c.name: c for c in (TINY, SMALL, MAMBA_110M, MAMBA_1_4B, MAMBA_2_8B)}


# ---------------------------------------------------------------------------
# Parameter initialization (matches the reference Mamba init).
# ---------------------------------------------------------------------------


def param_order(cfg: MambaConfig) -> List[str]:
    """Canonical flat ordering of parameters — the interchange contract with
    the rust runtime (recorded in artifacts/manifest.json)."""
    names = ["embedding"]
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        names += [
            p + "norm_w",
            p + "in_proj",
            p + "conv_w",
            p + "conv_b",
            p + "x_proj",
            p + "dt_proj",
            p + "dt_bias",
            p + "A_log",
            p + "D",
            p + "out_proj",
        ]
    names.append("norm_f_w")
    return names


def param_shapes(cfg: MambaConfig) -> Dict[str, Tuple[int, ...]]:
    d, di, n, r, w = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.dt_rank, cfg.d_conv
    shapes: Dict[str, Tuple[int, ...]] = {"embedding": (cfg.vocab_size, d)}
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        shapes[p + "norm_w"] = (d,)
        shapes[p + "in_proj"] = (d, 2 * di)
        shapes[p + "conv_w"] = (w, di)
        shapes[p + "conv_b"] = (di,)
        shapes[p + "x_proj"] = (di, r + 2 * n)
        shapes[p + "dt_proj"] = (r, di)
        shapes[p + "dt_bias"] = (di,)
        shapes[p + "A_log"] = (di, n)
        shapes[p + "D"] = (di,)
        shapes[p + "out_proj"] = (di, d)
    shapes["norm_f_w"] = (d,)
    return shapes


def init_params(cfg: MambaConfig, seed: int = 0) -> Params:
    key = jax.random.PRNGKey(seed)
    shapes = param_shapes(cfg)
    params: Params = {}
    dt_min, dt_max = 1e-3, 1e-1
    for name in param_order(cfg):
        shape = shapes[name]
        key, sub = jax.random.split(key)
        if name.endswith("norm_w") or name.endswith("norm_f_w"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith("A_log"):
            # S4D-real init: A = -(1..N) per channel.
            di, n = shape
            a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (di, 1))
            params[name] = jnp.log(a)
        elif name.endswith(".D"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith("dt_bias"):
            # inverse-softplus of log-uniform dt in [dt_min, dt_max]
            key, s2 = jax.random.split(key)
            u = jax.random.uniform(s2, shape)
            dt = jnp.exp(u * (math.log(dt_max) - math.log(dt_min)) + math.log(dt_min))
            params[name] = dt + jnp.log(-jnp.expm1(-dt))
        elif name.endswith("conv_b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name == "embedding":
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:
            fan_in = shape[0]
            scale = 1.0 / math.sqrt(fan_in)
            params[name] = jax.random.uniform(sub, shape, jnp.float32, -scale, scale)
    return params


# ---------------------------------------------------------------------------
# Forward.
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def mamba_block(
    params: Params,
    prefix: str,
    u: jax.Array,  # (B, L, d_model)
    position_indices: jax.Array,  # (B, L)
    cfg: MambaConfig,
) -> jax.Array:
    """One Mamba block (pre-norm residual form)."""
    p = lambda s: params[prefix + s]
    resid = u
    u = rms_norm(u, p("norm_w"), cfg.norm_eps)
    xz = u @ p("in_proj")  # (B, L, 2*d_inner)
    x, z = jnp.split(xz, 2, axis=-1)

    # sequence-wise op #1: packed causal depthwise conv (Pallas kernel)
    x = conv1d_packed(x, p("conv_w"), p("conv_b"), position_indices)
    x = jax.nn.silu(x)

    # selective projections
    dbc = x @ p("x_proj")  # (B, L, dt_rank + 2N)
    dt_low = dbc[..., : cfg.dt_rank]
    Bm = dbc[..., cfg.dt_rank : cfg.dt_rank + cfg.d_state]
    Cm = dbc[..., cfg.dt_rank + cfg.d_state :]
    dt = jax.nn.softplus(dt_low @ p("dt_proj") + p("dt_bias"))

    # sequence-wise op #2: packed selective scan (Pallas kernel)
    A = -jnp.exp(p("A_log"))
    y = ssm_packed(
        x, dt, A, Bm, Cm, p("D"), position_indices, mode=cfg.scan_mode
    )

    y = y * jax.nn.silu(z)
    return resid + y @ p("out_proj")


def forward(
    params: Params,
    tokens: jax.Array,  # (B, L) int32
    position_indices: jax.Array,  # (B, L) int32
    cfg: MambaConfig,
) -> jax.Array:
    """Token logits: (B, L, vocab).  Head is tied to the embedding."""
    h = params["embedding"][tokens]
    for i in range(cfg.n_layers):
        h = mamba_block(params, f"layers.{i}.", h, position_indices, cfg)
    h = rms_norm(h, params["norm_f_w"], cfg.norm_eps)
    return h @ params["embedding"].T


def loss_fn(
    params: Params,
    tokens: jax.Array,
    targets: jax.Array,
    position_indices: jax.Array,
    loss_mask: jax.Array,  # (B, L) f32; 0 on padding AND on final tokens of
    cfg: MambaConfig,  # each sequence (targets never cross boundaries)
) -> jax.Array:
    logits = forward(params, tokens, position_indices, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(loss_mask), 1.0)
    return jnp.sum(nll * loss_mask) / denom


# ---------------------------------------------------------------------------
# Optimizer: fused AdamW train step.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1


def _decay_mask(name: str) -> bool:
    """Weight decay only on matrices (standard GPT practice)."""
    return name.endswith(("in_proj", "x_proj", "dt_proj", "out_proj", "embedding"))


def adamw_update(
    params: Params,
    m: Params,
    v: Params,
    grads: Params,
    step: jax.Array,  # f32 scalar, 1-based
    opt: AdamWConfig,
) -> Tuple[Params, Params, Params]:
    b1c = 1.0 - opt.beta1**step
    b2c = 1.0 - opt.beta2**step
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        m_k = opt.beta1 * m[k] + (1.0 - opt.beta1) * g
        v_k = opt.beta2 * v[k] + (1.0 - opt.beta2) * jnp.square(g)
        upd = (m_k / b1c) / (jnp.sqrt(v_k / b2c) + opt.eps)
        if _decay_mask(k):
            upd = upd + opt.weight_decay * params[k]
        new_p[k] = params[k] - opt.lr * upd
        new_m[k] = m_k
        new_v[k] = v_k
    return new_p, new_m, new_v


def make_train_step(cfg: MambaConfig, opt: AdamWConfig):
    """(params, m, v, step, tokens, targets, pos, mask) →
    (params', m', v', loss) — the single fused artifact the trainer runs."""

    def train_step(params, m, v, step, tokens, targets, pos, mask):
        loss, grads = jax.value_and_grad(loss_fn)(
            params, tokens, targets, pos, mask, cfg
        )
        new_p, new_m, new_v = adamw_update(params, m, v, grads, step, opt)
        return new_p, new_m, new_v, loss

    return train_step


def make_grads_fn(cfg: MambaConfig):
    """(params, tokens, targets, pos, mask) → (loss, grads) — the worker
    half of the data-parallel path (leader all-reduces then applies)."""

    def grads_fn(params, tokens, targets, pos, mask):
        return jax.value_and_grad(loss_fn)(params, tokens, targets, pos, mask, cfg)

    return grads_fn


def make_adam_apply(cfg: MambaConfig, opt: AdamWConfig):
    """(params, m, v, step, grads) → (params', m', v') — leader-side update
    applied to all-reduced grads in the data-parallel scheme."""

    def apply_fn(params, m, v, step, grads):
        return adamw_update(params, m, v, grads, step, opt)

    return apply_fn


# ---------------------------------------------------------------------------
# Chunked (stateful) forward — the paper's §5 future-work extension:
# long sequences are cut at pack-row ends and their state (SSM hidden
# state + conv window tail) is carried into the next chunk, driving
# padding to zero and supporting unbounded sequence length.
# ---------------------------------------------------------------------------


def init_chunk_state(cfg: MambaConfig, batch: int):
    """Zero carry-state: one (h, conv_tail) pair per layer."""
    return [
        {
            "h": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
            "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), jnp.float32),
        }
        for _ in range(cfg.n_layers)
    ]


def mamba_block_with_state(
    params: Params,
    prefix: str,
    u: jax.Array,
    position_indices: jax.Array,
    cfg: MambaConfig,
    state,
):
    """One Mamba block with cross-chunk state carry.

    A chunk that *continues* a sequence has non-zero position indices at
    its first slot, which is exactly the condition under which the carried
    state flows in (the same boundary mask that isolates packed
    neighbours); a fresh-start chunk ignores the state.
    """
    from .kernels.conv1d import conv1d_packed_with_state
    from .kernels.selective_scan import ssm_packed_with_state

    p = lambda s: params[prefix + s]
    resid = u
    u = rms_norm(u, p("norm_w"), cfg.norm_eps)
    xz = u @ p("in_proj")
    x, z = jnp.split(xz, 2, axis=-1)

    x, new_tail = conv1d_packed_with_state(
        x, p("conv_w"), p("conv_b"), position_indices, state["conv"]
    )
    x = jax.nn.silu(x)

    dbc = x @ p("x_proj")
    dt_low = dbc[..., : cfg.dt_rank]
    Bm = dbc[..., cfg.dt_rank : cfg.dt_rank + cfg.d_state]
    Cm = dbc[..., cfg.dt_rank + cfg.d_state :]
    dt = jax.nn.softplus(dt_low @ p("dt_proj") + p("dt_bias"))

    A = -jnp.exp(p("A_log"))
    y, h_last = ssm_packed_with_state(
        x, dt, A, Bm, Cm, p("D"), position_indices, state["h"],
        mode=cfg.scan_mode,
    )
    y = y * jax.nn.silu(z)
    return resid + y @ p("out_proj"), {"h": h_last, "conv": new_tail}


def forward_chunked(
    params: Params,
    tokens: jax.Array,
    position_indices: jax.Array,
    cfg: MambaConfig,
    states,
):
    """Stateful forward over one chunk; returns (logits, new_states).

    Feeding consecutive chunks of a long sequence (position indices
    continuing across chunks) reproduces the unchunked forward exactly —
    asserted by `tests/test_chunked.py`.  Note: the carried SSM state is
    the state at each row's final slot, so this mode targets the
    zero-padding regime the paper's §5 describes (rows end mid-sequence,
    not in padding).
    """
    h = params["embedding"][tokens]
    new_states = []
    for i in range(cfg.n_layers):
        h, st = mamba_block_with_state(
            params, f"layers.{i}.", h, position_indices, cfg, states[i]
        )
        new_states.append(st)
    h = rms_norm(h, params["norm_f_w"], cfg.norm_eps)
    return h @ params["embedding"].T, new_states
