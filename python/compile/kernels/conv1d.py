"""Pallas packed causal depthwise conv1d (paper §3.3, Algorithm 1).

A causal depthwise convolution of width W computes

    y[t, d] = bias[d] + Σ_j  w[j, d] · x[t - (W-1) + j, d]

When sequences are packed, the sliding window crosses sequence boundaries
(the red line in the paper's Fig 3b): the first tokens of a sequence would
read the tail of the *previous* sequence.  Algorithm 1 terminates the
window early for boundary elements (``index < width``); equivalently, tap
``j`` — which reaches back ``s = W-1-j`` steps — is only active where the
output token is at least ``s`` tokens into its own sequence:

    active(t, j)  ⇔  position_indices[t] ≥ W-1-j

The backward pass needs the mirrored condition for ``dx`` (a token's
gradient collects from outputs *later* in the same sequence); the mask
there is ``position_indices[t + s] ≥ s``, which the kernel reads from a
shifted view of the same index plane — this is the paper's §3.5 'reverse
indices obtained from the position indices of the last conv_width
elements', staged through the BlockSpec-managed block instead of CUDA
shared memory.

Kernels are lowered with ``interpret=True`` (CPU PJRT; see DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_D_BLOCK = 128


def _d_block(D: int, d_block: int) -> int:
    blk = min(D, d_block)
    while D % blk != 0:
        blk -= 1
    return blk


def _conv_fwd_kernel(idx_ref, x_ref, w_ref, b_ref, y_ref, *, width: int):
    pos = idx_ref[0, :]  # (L,) — staged once per grid cell
    x = x_ref[0]  # (L, blk)
    L = x.shape[0]
    y = jnp.zeros_like(x) + b_ref[:][None, :]
    for j in range(width):
        s = (width - 1) - j  # tap j reaches back s steps
        xs = jnp.pad(x, ((s, 0), (0, 0)))[:L]
        ok = (pos >= s).astype(x.dtype)[:, None]
        y = y + w_ref[j, :][None, :] * xs * ok
    y_ref[0] = y


def _conv_bwd_dx_kernel(idx_ref, g_ref, w_ref, dx_ref, *, width: int):
    """dx[t] = Σ_j w[j] · g[t + s_j] · [pos[t + s_j] ≥ s_j]  (s_j = W-1-j).

    The boundary test uses the *output* token's position index, read from a
    forward-shifted view of the index plane (the 'reverse indices').
    """
    pos = idx_ref[0, :]
    g = g_ref[0]  # (L, blk)
    L = g.shape[0]
    dx = jnp.zeros_like(g)
    for j in range(width):
        s = (width - 1) - j
        gs = jnp.pad(g, ((0, s), (0, 0)))[s : s + L]  # g[t+s]
        ps = jnp.pad(pos, (0, s), constant_values=0)[s : s + L]  # pos[t+s]
        ok = (ps >= s).astype(g.dtype)[:, None]
        dx = dx + w_ref[j, :][None, :] * gs * ok
    dx_ref[0] = dx


def conv1d_fwd_pallas(
    x: jax.Array,  # (B, L, D)
    w: jax.Array,  # (W, D)
    bias: jax.Array,  # (D,)
    position_indices: jax.Array,  # (B, L) int32
    *,
    d_block: int = DEFAULT_D_BLOCK,
) -> jax.Array:
    Bsz, L, D = x.shape
    W = w.shape[0]
    blk = _d_block(D, d_block)
    grid = (Bsz, D // blk)
    return pl.pallas_call(
        functools.partial(_conv_fwd_kernel, width=W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L), lambda i, j: (i, 0)),
            pl.BlockSpec((1, L, blk), lambda i, j: (i, 0, j)),
            pl.BlockSpec((W, blk), lambda i, j: (0, j)),
            pl.BlockSpec((blk,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((1, L, blk), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(position_indices, x, w, bias)


def _conv_dx_pallas(g, w, position_indices, *, d_block: int = DEFAULT_D_BLOCK):
    Bsz, L, D = g.shape
    W = w.shape[0]
    blk = _d_block(D, d_block)
    grid = (Bsz, D // blk)
    return pl.pallas_call(
        functools.partial(_conv_bwd_dx_kernel, width=W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L), lambda i, j: (i, 0)),
            pl.BlockSpec((1, L, blk), lambda i, j: (i, 0, j)),
            pl.BlockSpec((W, blk), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, L, blk), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct(g.shape, g.dtype),
        interpret=True,
    )(position_indices, g, w)


# ---------------------------------------------------------------------------
# Differentiable wrapper.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def conv1d_packed(
    x: jax.Array, w: jax.Array, bias: jax.Array, position_indices: jax.Array
) -> jax.Array:
    """Packed causal depthwise conv1d; differentiable in x, w, bias."""
    return conv1d_fwd_pallas(x, w, bias, position_indices)


def _conv_fwd(x, w, bias, position_indices):
    y = conv1d_fwd_pallas(x, w, bias, position_indices)
    return y, (x, w, position_indices)


def _conv_bwd(res, g):
    x, w, position_indices = res
    W = w.shape[0]
    L = x.shape[1]
    dx = _conv_dx_pallas(g, w, position_indices)
    # dw[j] = Σ_{b,t} g[t] · x[t - s_j] · [pos[t] ≥ s_j]   — small reduction,
    # done in jnp (it is a (W, D) output; no kernel needed).
    dws = []
    pos = position_indices
    for j in range(W):
        s = (W - 1) - j
        xs = jnp.pad(x, ((0, 0), (s, 0), (0, 0)))[:, :L]
        ok = (pos >= s).astype(x.dtype)[..., None]
        dws.append(jnp.sum(g * xs * ok, axis=(0, 1)))
    dw = jnp.stack(dws, axis=0)
    dbias = jnp.sum(g, axis=(0, 1))
    return dx, dw, dbias, None


conv1d_packed.defvjp(_conv_fwd, _conv_bwd)


def conv1d_dense(x: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """Unpacked causal conv baseline: every row is one sequence."""
    Bsz, L, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None], (Bsz, L))
    return conv1d_packed(x, w, bias, pos)


# ---------------------------------------------------------------------------
# Stateful conv: cross-chunk tail carry (paper §5 future-work: split
# sequences continue across packed rows).
# ---------------------------------------------------------------------------


def conv1d_packed_with_state(
    x: jax.Array,  # (B, L, D)
    w: jax.Array,
    bias: jax.Array,
    position_indices: jax.Array,
    x_tail: jax.Array,  # (B, W-1, D) — final inputs of the previous chunk
):
    """Packed causal conv whose window can reach into the previous chunk.

    The previous chunk's last ``W-1`` inputs are prepended; position
    indices for the prefix continue backwards (``pos_0 - (W-1) .. pos_0-1``)
    so the same tap mask admits them exactly when the first tokens of this
    chunk are deep enough into a *continued* sequence — a fresh sequence
    (pos starting at 0) masks the prefix out entirely.  Returns
    (y, new_x_tail).
    """
    W = w.shape[0]
    Bsz, L, D = x.shape
    pad = W - 1
    x_ext = jnp.concatenate([x_tail, x], axis=1)  # (B, L+W-1, D)
    pos0 = position_indices[:, :1]
    prefix_pos = pos0 + jnp.arange(-pad, 0, dtype=jnp.int32)[None, :]
    # fresh-start rows: prefix positions go negative → clamp to -1, which
    # fails every `>= s` tap test (the tail is ignored, as it must be)
    prefix_pos = jnp.maximum(prefix_pos, -1)
    pos_ext = jnp.concatenate([prefix_pos, position_indices], axis=1)
    y_ext = conv1d_packed(x_ext, w, bias, pos_ext)
    y = y_ext[:, pad:]
    return y, x_ext[:, L:][:, -pad:] if pad > 0 else x_ext[:, :0]
