"""Pallas packed selective-scan kernel (paper §3.4, Algorithm 2).

The SSM recurrence h_t = Ā_t h_{t-1} + B̄_t x_t is parallelized as an
associative scan over pairs (Ā, B̄x) with the combine

    (a2, b2) ∘ (a1, b1) = (a1·a2, a2·b1 + b2)        # c1 applied first

PackMamba's modification is input-side: set Ā_t → 0 wherever
``position_indices[t] == 0`` (a packed-sequence start).  Because the combine
is associative and every prefix product crossing a boundary then contains a
zero factor, no state crosses sequence boundaries — for *any* scan schedule.
The kernel therefore stays a plain parallel scan; the boundary mask is one
select against an index plane that is loaded once per grid cell (the paper's
§3.5 shared-memory/coalescing co-optimization maps to the BlockSpec-staged
index block here; see DESIGN.md §Hardware-Adaptation).

Two schedules are provided (ablation: ``benches/fig2`` + DESIGN.md §8):

* ``blelloch`` (default, paper-faithful): work-efficient up/down-sweep,
  ``2·log2(L')`` ladder steps over an internally padded power-of-two L' —
  this internal padding is exactly the plateau effect the paper measures in
  Fig 2.
* ``hillis``: depth-efficient inclusive scan, ``log2(L)`` steps, no internal
  padding.

Kernels are lowered with ``interpret=True`` (CPU PJRT; see DESIGN.md).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Channel block per grid cell.  128 aligns with TPU VPU/MXU lane width; the
# VMEM-equivalent footprint per cell is L·128·N·4B per plane (see DESIGN §9).
DEFAULT_D_BLOCK = 128


def _combine(a1, b1, a2, b2):
    """(a2,b2) ∘ (a1,b1): earlier element (1) is applied first."""
    return a1 * a2, a2 * b1 + b2


def _hillis_steele(a, b):
    """Inclusive scan along axis 0 of (L, ...) arrays; log2(L) steps."""
    L = a.shape[0]
    d = 1
    while d < L:
        pad = [(d, 0)] + [(0, 0)] * (a.ndim - 1)
        a_prev = jnp.pad(a, pad, constant_values=1.0)[:L]
        b_prev = jnp.pad(b, pad, constant_values=0.0)[:L]
        # identity for t < d is (1, 0): those rows combine with identity.
        ident = (jnp.arange(L) < d).astype(a.dtype)
        ident = ident.reshape((L,) + (1,) * (a.ndim - 1))
        a_prev = a_prev * (1.0 - ident) + ident  # (1,0) where out of range
        b_prev = b_prev * (1.0 - ident)
        a, b = _combine(a_prev, b_prev, a, b)
        d *= 2
    return a, b


def _blelloch(a, b):
    """Inclusive scan along axis 0, Blelloch up/down-sweep (2·log2(L') steps).

    Internally pads L to the next power of two with the identity (1, 0) —
    the paper's Fig 2 'internal padding' effect.  The down-sweep produces the
    exclusive scan; one final combine with the inputs yields the inclusive
    result.
    """
    L = a.shape[0]
    Lp = 1
    while Lp < L:
        Lp *= 2
    pad = [(0, Lp - L)] + [(0, 0)] * (a.ndim - 1)
    a0 = jnp.pad(a, pad, constant_values=1.0)
    b0 = jnp.pad(b, pad, constant_values=0.0)
    ar, br = a0, b0
    idx = jnp.arange(Lp).reshape((Lp,) + (1,) * (a.ndim - 1))

    # Up-sweep: at stride d, positions t ≡ 2d-1 (mod 2d) absorb t-d.
    d = 1
    while d < Lp:
        sel = (idx % (2 * d)) == (2 * d - 1)
        shift = [(d, 0)] + [(0, 0)] * (a.ndim - 1)
        a_prev = jnp.pad(ar, shift, constant_values=1.0)[:Lp]
        b_prev = jnp.pad(br, shift, constant_values=0.0)[:Lp]
        na, nb = _combine(a_prev, b_prev, ar, br)
        ar = jnp.where(sel, na, ar)
        br = jnp.where(sel, nb, br)
        d *= 2

    # Down-sweep: clear the root to identity, then swap+combine downwards.
    root = idx == (Lp - 1)
    ar = jnp.where(root, 1.0, ar)
    br = jnp.where(root, 0.0, br)
    d = Lp // 2
    while d >= 1:
        sel_hi = (idx % (2 * d)) == (2 * d - 1)  # right child
        sel_lo = (idx % (2 * d)) == (d - 1)  # left child
        shift_dn = [(d, 0)] + [(0, 0)] * (a.ndim - 1)
        shift_up = [(0, d)] + [(0, 0)] * (a.ndim - 1)
        a_lo = jnp.pad(ar, shift_dn, constant_values=1.0)[:Lp]  # value at t-d
        b_lo = jnp.pad(br, shift_dn, constant_values=0.0)[:Lp]
        a_hi = jnp.pad(ar, shift_up, constant_values=1.0)[d:]  # value at t+d
        b_hi = jnp.pad(br, shift_up, constant_values=0.0)[d:]
        # left child receives the parent's (pre-update) prefix value
        na_lo, nb_lo = a_hi, b_hi
        # right child = parent-prefix then left-subtree sum: the parent
        # prefix covers the earlier elements, so it is the first argument.
        na_hi, nb_hi = _combine(ar, br, a_lo, b_lo)
        ar = jnp.where(sel_lo, na_lo, jnp.where(sel_hi, na_hi, ar))
        br = jnp.where(sel_lo, nb_lo, jnp.where(sel_hi, nb_hi, br))
        d //= 2
    # ar/br now hold the *exclusive* scan; combine once with inputs.
    ai, bi = _combine(ar, br, a0, b0)
    return ai[:L], bi[:L]


_SCANS = {"hillis": _hillis_steele, "blelloch": _blelloch}


def _scan_masked_kernel(idx_ref, a_ref, b_ref, h_ref, *, mode: str):
    """Grid cell: one (batch row, channel block).  Applies the boundary mask
    from the staged index plane, then runs the parallel scan ladder."""
    pos = idx_ref[0, :]  # (L,) int32 — loaded once per cell
    mask = (pos != 0).astype(a_ref.dtype)  # Ā → 0 at sequence starts
    a = a_ref[0] * mask[:, None, None]
    b = b_ref[0]
    _, h = _SCANS[mode](a, b)
    h_ref[0] = h


def _scan_plain_kernel(a_ref, b_ref, h_ref, *, mode: str):
    a = a_ref[0]
    b = b_ref[0]
    _, h = _SCANS[mode](a, b)
    h_ref[0] = h


def _d_block(D: int, d_block: int) -> int:
    blk = min(D, d_block)
    while D % blk != 0:  # shapes in this repo are powers of two, but be safe
        blk -= 1
    return blk


def scan_masked_pallas(
    a: jax.Array,
    b: jax.Array,
    position_indices: jax.Array,
    *,
    mode: str = "blelloch",
    d_block: int = DEFAULT_D_BLOCK,
) -> jax.Array:
    """Packed parallel scan.  a, b: (B, L, D, N); position_indices: (B, L)."""
    Bsz, L, D, N = a.shape
    blk = _d_block(D, d_block)
    grid = (Bsz, D // blk)
    return pl.pallas_call(
        functools.partial(_scan_masked_kernel, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L), lambda i, j: (i, 0)),  # index plane: once/cell
            pl.BlockSpec((1, L, blk, N), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, L, blk, N), lambda i, j: (i, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, blk, N), lambda i, j: (i, 0, j, 0)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=True,
    )(position_indices, a, b)


def scan_plain_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    mode: str = "blelloch",
    d_block: int = DEFAULT_D_BLOCK,
) -> jax.Array:
    """Unmasked parallel scan (used by the backward pass on pre-masked
    inputs, and as the non-packed baseline)."""
    Bsz, L, D, N = a.shape
    blk = _d_block(D, d_block)
    grid = (Bsz, D // blk)
    return pl.pallas_call(
        functools.partial(_scan_plain_kernel, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, blk, N), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, L, blk, N), lambda i, j: (i, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, blk, N), lambda i, j: (i, 0, j, 0)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=True,
    )(a, b)


# ---------------------------------------------------------------------------
# Differentiable segmented scan: custom VJP whose backward pass is *another*
# pair of scans (the paper's §3.4 'backward process consists of another two
# scan operators, with the same Ā → 0 modification').
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def segmented_scan(
    a: jax.Array, b: jax.Array, boundary_mask: jax.Array, mode: str = "blelloch"
) -> jax.Array:
    """h_t = (a_t·m_t) h_{t-1} + b_t with m the boundary mask (0 at starts).

    a, b: (B, L, D, N).  boundary_mask: (B, L) float (1.0 inside a sequence,
    0.0 at each sequence start) — float so the VJP machinery can thread a
    (zero) cotangent for it.
    """
    am = a * boundary_mask[:, :, None, None]
    return scan_plain_pallas(am, b, mode=mode)


def _segscan_fwd(a, b, boundary_mask, mode):
    am = a * boundary_mask[:, :, None, None]
    h = scan_plain_pallas(am, b, mode=mode)
    return h, (am, h, boundary_mask)


def _segscan_bwd(mode, res, dh):
    am, h, boundary_mask = res
    # g_t = dh_t + ā_{t+1} g_{t+1}: a reverse scan with the multiplier
    # shifted one step left (ā at a start is already 0, which also stops
    # gradients from flowing backwards across boundaries).
    a_next = jnp.concatenate([am[:, 1:], jnp.zeros_like(am[:, :1])], axis=1)
    g_rev = scan_plain_pallas(
        jnp.flip(a_next, axis=1), jnp.flip(dh, axis=1), mode=mode
    )
    g = jnp.flip(g_rev, axis=1)
    h_prev = jnp.concatenate([jnp.zeros_like(h[:, :1]), h[:, :-1]], axis=1)
    da = g * h_prev * boundary_mask[:, :, None, None]
    db = g
    dmask = jnp.zeros_like(boundary_mask)
    return da, db, dmask


segmented_scan.defvjp(_segscan_fwd, _segscan_bwd)


def ssm_packed(
    x: jax.Array,  # (B, L, D)
    dt: jax.Array,  # (B, L, D)
    A: jax.Array,  # (D, N)
    B: jax.Array,  # (B, L, N)
    C: jax.Array,  # (B, L, N)
    D: jax.Array,  # (D,)
    position_indices: jax.Array,  # (B, L) int32
    *,
    mode: str = "blelloch",
) -> jax.Array:
    """Full packed selective-scan operator: discretize, scan, project.

    Matches ``ref.ssm_packed_ref`` exactly (same discretization), but runs
    the recurrence through the Pallas parallel-scan kernel and is
    differentiable end to end (scan VJP above, rest via jax autodiff).
    """
    a = jnp.exp(dt[..., None] * A[None, None])  # (B, L, D, N)
    b = (dt * x)[..., None] * B[:, :, None, :]  # (B, L, D, N)
    mask = (position_indices != 0).astype(x.dtype)
    h = segmented_scan(a, b, mask, mode)
    y = jnp.einsum("bldn,bln->bld", h, C)
    return y + x * D[None, None]


def ssm_dense(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    D: jax.Array,
    *,
    mode: str = "blelloch",
) -> jax.Array:
    """Unpacked selective scan (baseline single-sequence / padding schemes).

    Identical to ``ssm_packed`` with an all-ones mask except position 0.
    """
    Bsz, L, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None], (Bsz, L))
    return ssm_packed(x, dt, A, B, C, D, pos, mode=mode)


# ---------------------------------------------------------------------------
# Stateful scan: the paper's §5 future-work extension ("sequences cut into
# two parts at the end of long sequences, with states still being passed
# between these parts ... reducing padding to zero").
# ---------------------------------------------------------------------------


def segmented_scan_with_state(
    a: jax.Array,
    b: jax.Array,
    boundary_mask: jax.Array,
    h0: jax.Array,  # (B, D, N) — carried state from the previous chunk
    mode: str = "blelloch",
) -> Tuple[jax.Array, jax.Array]:
    """Segmented scan with an initial state.

    The carried state folds into the first step as an input transform:
    ``b'_0 = b_0 + (a_0 · m_0) · h0`` — if the chunk *continues* a sequence
    its first position index is non-zero (m_0 = 1) and the state flows in;
    if it starts a fresh sequence (m_0 = 0) the state is discarded by the
    same mask that isolates packed neighbours.  Returns (h, h_last).
    """
    a0m = a[:, 0] * boundary_mask[:, 0][:, None, None]
    b = b.at[:, 0].add(a0m * h0)
    h = segmented_scan(a, b, boundary_mask, mode)
    return h, h[:, -1]


def ssm_packed_with_state(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    D: jax.Array,
    position_indices: jax.Array,
    h0: jax.Array,
    *,
    mode: str = "blelloch",
) -> Tuple[jax.Array, jax.Array]:
    """``ssm_packed`` with cross-chunk state carry; returns (y, h_last)."""
    a = jnp.exp(dt[..., None] * A[None, None])
    b = (dt * x)[..., None] * B[:, :, None, :]
    mask = (position_indices != 0).astype(x.dtype)
    h, h_last = segmented_scan_with_state(a, b, mask, h0, mode)
    y = jnp.einsum("bldn,bln->bld", h, C)
    return y + x * D[None, None], h_last
