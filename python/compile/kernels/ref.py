"""Pure-jnp correctness oracles for the PackMamba kernels.

Everything here is written for clarity, not speed: serial ``lax.scan`` for
the SSM recurrence, explicit tap loops for the causal conv.  The Pallas
kernels in ``selective_scan.py`` / ``conv1d.py`` are tested against these
in ``python/tests/`` (exact semantics, allclose numerics).

Notation follows the paper (§3.4):

    h_t = Ā_t h_{t-1} + B̄_t x_t          (1a)
    y_t = C_t h_t (+ D x_t)               (1b)
    Ā   = exp(Δ A)                        (2a)
    B̄ x = Δ B x    (Euler/ZOH-B discretization used by Mamba)

The packed variants take ``position_indices`` and must satisfy PUI:
running the packed op on pack(S) and unpacking equals running the plain op
on each sequence separately.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Core first-order recurrence h_t = a_t h_{t-1} + b_t  (the scan the paper
# parallelizes with scanMul/scanAdd).
# ---------------------------------------------------------------------------


def linear_scan_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Serial reference scan along axis 1.

    a, b: (B, L, ...) — returns h with h[:, t] = a[:, t] * h[:, t-1] + b[:, t],
    starting from h_{-1} = 0.
    """

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    a_t = jnp.moveaxis(a, 1, 0)
    b_t = jnp.moveaxis(b, 1, 0)
    h0 = jnp.zeros_like(a[:, 0])
    _, hs = jax.lax.scan(step, h0, (a_t, b_t))
    return jnp.moveaxis(hs, 0, 1)


def segmented_scan_ref(
    a: jax.Array, b: jax.Array, position_indices: jax.Array
) -> jax.Array:
    """Packed scan: zero the multiplicative term at sequence starts.

    This is the paper's §3.4 modification: Ā_{position_indices==0} → 0 kills
    every prefix product crossing a boundary, so no state passes between
    packed sequences.  position_indices: (B, L) int32.
    """
    mask = (position_indices != 0).astype(a.dtype)
    mask = mask.reshape(mask.shape + (1,) * (a.ndim - mask.ndim))
    return linear_scan_ref(a * mask, b)


# ---------------------------------------------------------------------------
# Selective-scan (SSM) operator: full Mamba S6 layer semantics.
# ---------------------------------------------------------------------------


def ssm_ref(
    x: jax.Array,  # (B, L, D)     post-conv activations
    dt: jax.Array,  # (B, L, D)    discretization step (post-softplus)
    A: jax.Array,  # (D, N)        continuous state matrix (negative)
    B: jax.Array,  # (B, L, N)     input projection (selective)
    C: jax.Array,  # (B, L, N)     output projection (selective)
    D: jax.Array,  # (D,)          skip connection
) -> jax.Array:
    """Reference selective scan, serial over L.  Returns y: (B, L, D)."""
    a = jnp.exp(dt[..., None] * A[None, None])  # (B, L, D, N)
    b = (dt * x)[..., None] * B[:, :, None, :]  # (B, L, D, N)
    h = linear_scan_ref(a, b)  # (B, L, D, N)
    y = jnp.einsum("bldn,bln->bld", h, C)
    return y + x * D[None, None]


def ssm_packed_ref(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    D: jax.Array,
    position_indices: jax.Array,
) -> jax.Array:
    """Packed selective scan oracle (paper Algorithm 2 semantics)."""
    a = jnp.exp(dt[..., None] * A[None, None])
    b = (dt * x)[..., None] * B[:, :, None, :]
    h = segmented_scan_ref(a, b, position_indices)
    y = jnp.einsum("bldn,bln->bld", h, C)
    return y + x * D[None, None]


# ---------------------------------------------------------------------------
# Causal depthwise conv1d.
# ---------------------------------------------------------------------------


def conv1d_ref(x: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """Causal depthwise conv. x: (B, L, D), w: (W, D), bias: (D,).

    y[:, t, d] = bias[d] + sum_j w[j, d] * x[:, t - (W-1) + j, d]
    with out-of-range x treated as zero (standard left zero-padding).
    """
    W = w.shape[0]
    y = jnp.zeros_like(x) + bias[None, None]
    for j in range(W):
        shift = (W - 1) - j  # how far back tap j reaches
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        y = y + w[j][None, None] * xs
    return y


def conv1d_packed_ref(
    x: jax.Array, w: jax.Array, bias: jax.Array, position_indices: jax.Array
) -> jax.Array:
    """Packed causal conv oracle (paper Algorithm 1 semantics).

    Tap j (reaching back ``shift = W-1-j`` steps) only contributes where the
    output token is at least ``shift`` deep into its own sequence, i.e.
    position_indices >= shift.  This is exactly the early termination of the
    convolution loop for boundary elements (index < width) in Algorithm 1.
    """
    W = w.shape[0]
    y = jnp.zeros_like(x) + bias[None, None]
    for j in range(W):
        shift = (W - 1) - j
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        ok = (position_indices >= shift).astype(x.dtype)[..., None]
        y = y + w[j][None, None] * xs * ok
    return y


# ---------------------------------------------------------------------------
# Per-sequence oracles: the "unpacked" side of the PUI equation.
# ---------------------------------------------------------------------------


def ssm_per_sequence(x, dt, A, B, C, D, lengths):
    """Run ssm_ref on each original sequence of a single packed row.

    x, dt: (L, D); B, C: (L, N).  Returns the concatenation along L, i.e.
    pack(f(S)) for comparison against f(pack(S)).
    """
    outs = []
    off = 0
    for n in lengths:
        sl = slice(off, off + n)
        outs.append(
            ssm_ref(x[None, sl], dt[None, sl], A, B[None, sl], C[None, sl], D)[0]
        )
        off += n
    return jnp.concatenate(outs, axis=0) if outs else jnp.zeros_like(x[:0])


def conv1d_per_sequence(x, w, bias, lengths):
    """Per-sequence causal conv of one packed row.  x: (L, D)."""
    outs = []
    off = 0
    for n in lengths:
        outs.append(conv1d_ref(x[None, off : off + n], w, bias)[0])
        off += n
    return jnp.concatenate(outs, axis=0) if outs else jnp.zeros_like(x[:0])
