//! Quickstart: pack variable-length sequences, run the model forward
//! through the AOT artifact, unpack, and verify Packing-Unpacking
//! Invariance (PUI) against per-sequence execution.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::path::Path;
use std::rc::Rc;

use packmamba::coordinator::TrainState;
use packmamba::packing::{unpack_outputs, PackedBatch, PackedRow, Sequence};
use packmamba::runtime::{HostValue, Runtime};
use packmamba::tensor::Tensor;
use packmamba::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    packmamba::util::logging::init();
    let runtime = Runtime::load(Path::new("artifacts"))?;

    // 1. initialize model parameters via the init artifact (XLA numerics)
    let state = TrainState::init(&runtime, "tiny")?;
    println!("tiny Mamba: {} parameters", state.param_count());

    // 2. three variable-length "documents"
    let mut rng = Pcg64::new(7, 0);
    let seqs: Vec<Sequence> = [50usize, 38, 30]
        .iter()
        .enumerate()
        .map(|(i, &n)| Sequence {
            tokens: (0..n).map(|_| 1 + rng.next_below(511) as i32).collect(),
            id: i as u64,
        })
        .collect();

    // 3. pack them into one 128-slot row (+ empty rows: the artifact
    //    geometry is fixed at compile time, rows=4)
    let packed = PackedBatch::from_rows(
        &[
            PackedRow { sequences: seqs.clone() },
            PackedRow::default(),
            PackedRow::default(),
            PackedRow::default(),
        ],
        128,
    );
    println!(
        "packed {} sequences into {}x{} ({}% padding)",
        seqs.len(),
        packed.rows(),
        packed.pack_len(),
        (packed.padding_rate() * 100.0).round()
    );

    // 4. run the packed forward
    let fwd = runtime.executable("forward_tiny_b4x128")?;
    let mut args: Vec<HostValue> =
        state.params.iter().map(|p| HostValue::F32(p.clone())).collect();
    args.push(HostValue::I32(packed.tokens.clone()));
    args.push(HostValue::I32(packed.position_indices.clone()));
    let logits: Tensor = fwd.run(&args)?.remove(0).into_f32()?;
    println!("packed logits: {:?}", logits.shape());

    // 5. unpack per-sequence outputs
    let per_seq = unpack_outputs(&packed, &logits);
    for (id, vals) in &per_seq {
        println!("  sequence {id}: {} logit values", vals.len());
    }

    // 6. PUI check: each sequence alone must give identical logits
    let buckets = [32usize, 64, 128];
    let mut worst = 0f32;
    let mut off = 0usize;
    for s in &seqs {
        let bucket = buckets.iter().copied().find(|&b| b >= s.len()).unwrap();
        let solo_batch = PackedBatch::from_rows(
            &[PackedRow { sequences: vec![s.clone()] }],
            bucket,
        );
        let exe = runtime.executable(&format!("forward_tiny_b1x{bucket}"))?;
        let mut args: Vec<HostValue> =
            state.params.iter().map(|p| HostValue::F32(p.clone())).collect();
        args.push(HostValue::I32(solo_batch.tokens.clone()));
        args.push(HostValue::I32(solo_batch.position_indices.clone()));
        let solo = exe.run(&args)?.remove(0).into_f32()?;
        for t in 0..s.len() {
            for v in 0..512 {
                let a = logits.at(&[0, off + t, v]);
                let b = solo.at(&[0, t, v]);
                worst = worst.max((a - b).abs());
            }
        }
        off += s.len();
    }
    println!("PUI max |packed - solo| over all logits: {worst:.2e}");
    anyhow::ensure!(worst < 1e-3, "PUI violated!");
    println!("PUI holds: f(S) == unpack(f(pack(S)))  ✓");
    let _ = Rc::strong_count(&runtime);
    Ok(())
}
